//! Shape-level reproduction checks of the paper's headline claims
//! (§VII-B/E), on a reduced grid so the suite stays fast.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::experiments::{cluster, model};
use galvatron::search::baselines::{run_method, run_partition_ablation};

const MAX_BATCH: usize = 128;

fn tp(method: &str, mname: &str, cl: &str, budget: f64) -> Option<f64> {
    run_method(method, &model(mname), &cluster(cl, budget), MAX_BATCH).map(|o| o.throughput())
}

#[test]
fn bmw_beats_every_baseline_on_bert_12g() {
    // Table II's core shape: Galvatron-BMW >= every baseline per cell.
    let bmw = tp("Galvatron-BMW", "bert-huge-32", "titan8", 12.0).expect("feasible");
    for m in [
        "PyTorch DDP (DP)",
        "Megatron (TP)",
        "PyTorch GPipe (PP)",
        "FSDP/ZeRO-3 (SDP)",
        "DeepSpeed 3D",
        "Galvatron (DP+TP)",
        "Galvatron (DP+PP)",
        "Galvatron",
    ] {
        let t = tp(m, "bert-huge-32", "titan8", 12.0).unwrap_or(0.0);
        assert!(bmw >= t * 0.999, "{m}: bmw {bmw} < {t}");
    }
}

#[test]
fn oom_pattern_matches_table2() {
    // BERT-Huge-48 at 8G: DP-replicated methods OOM (model states alone
    // are ~15.8 GB); memory-sharding methods survive somewhere.
    assert!(tp("PyTorch DDP (DP)", "bert-huge-48", "titan8", 8.0).is_none());
    assert!(tp("Galvatron (DP+TP)", "bert-huge-48", "titan8", 8.0).is_none());
    // BMW always finds something when *any* strategy fits.
    let bmw = tp("Galvatron-BMW", "bert-huge-48", "titan8", 8.0);
    let base = tp("Galvatron-Base", "bert-huge-48", "titan8", 8.0);
    assert!(bmw.is_some() && base.is_some(), "CKPT+sharding must fit 48 layers at 8G");
}

#[test]
fn ckpt_grows_batch_size_claim() {
    if cfg!(debug_assertions) {
        eprintln!("skipping in debug build (planner-heavy; run with --release)");
        return;
    }
    // §VII-B: "CKPT's memory efficiency facilitates larger training batch".
    let base = run_method("Galvatron-Base", &model("bert-huge-32"), &cluster("titan8", 8.0), 256);
    let no_ckpt = run_method("Galvatron", &model("bert-huge-32"), &cluster("titan8", 8.0), 256);
    let b_ckpt = base.map(|o| o.plan.batch).unwrap_or(0);
    let b_plain = no_ckpt.map(|o| o.plan.batch).unwrap_or(0);
    assert!(b_ckpt >= b_plain, "ckpt batch {b_ckpt} < plain {b_plain}");
}

#[test]
fn biobj_at_least_matches_fixed_partitions_on_imbalanced_model() {
    if cfg!(debug_assertions) {
        eprintln!("skipping in debug build (planner-heavy; run with --release)");
        return;
    }
    // Table V shape: bi-objective >= max(mem-balanced, time-balanced).
    let mp = model("t5-512/4-32");
    let cl = cluster("a100x16", 8.0);
    let bi = run_method("Galvatron (1F1B+Bi-obj)", &mp, &cl, MAX_BATCH).map(|o| o.throughput());
    let mem = run_partition_ablation("mem", &mp, &cl, MAX_BATCH).map(|o| o.throughput());
    let time = run_partition_ablation("time", &mp, &cl, MAX_BATCH).map(|o| o.throughput());
    if let Some(bi) = bi {
        for (name, other) in [("mem", mem), ("time", time)] {
            if let Some(o) = other {
                assert!(bi >= o * 0.97, "bi-obj {bi} < {name} {o}");
            }
        }
    }
}

#[test]
fn nlp_vs_cv_strategy_preference() {
    if cfg!(debug_assertions) {
        eprintln!("skipping in debug build (planner-heavy; run with --release)");
        return;
    }
    // §VII-B: CV models (big params, small activations) benefit more from
    // SDP than NLP models do at generous budgets.
    let vit_sdp = tp("FSDP/ZeRO-3 (SDP)", "vit-huge-32", "titan8", 16.0).unwrap_or(0.0);
    let vit_tp = tp("Megatron (TP)", "vit-huge-32", "titan8", 16.0).unwrap_or(0.0);
    assert!(vit_sdp > vit_tp, "ViT: SDP {vit_sdp} must beat TP {vit_tp}");
}

#[test]
fn larger_cluster_scales_throughput() {
    if cfg!(debug_assertions) {
        eprintln!("skipping in debug build (planner-heavy; run with --release)");
        return;
    }
    // §VII-D: 16 GPUs give ~2x the 8-GPU throughput for BMW.
    let t8 = tp("Galvatron-BMW", "vit-huge-32", "titan8", 16.0).expect("8gpu");
    let t16 = tp("Galvatron-BMW", "vit-huge-32", "titan16", 16.0).expect("16gpu");
    assert!(t16 > 1.5 * t8, "16-GPU {t16} not ~2x 8-GPU {t8}");
}

#[test]
fn high_perf_cluster_beats_low_perf() {
    if cfg!(debug_assertions) {
        eprintln!("skipping in debug build (planner-heavy; run with --release)");
        return;
    }
    let lo = tp("Galvatron-BMW", "bert-huge-32", "titan16", 16.0).expect("lo");
    let hi = tp("Galvatron-BMW", "bert-huge-32", "a100x16", 16.0).expect("hi");
    assert!(hi > 2.0 * lo, "A100 cluster {hi} must far exceed TITAN {lo}");
}
