//! Integration tests for the `galvatron serve` daemon core.
//!
//! The contract under test: the daemon is a transport around the exact
//! CLI planning pipeline — every served artifact is byte-identical to
//! `galvatron plan` output, identical in-flight requests collapse onto
//! one search, warm starts answer from the persistent store without
//! searching, and a malformed request produces a typed error without
//! killing the daemon.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use galvatron::api::{MethodSpec, PlanRequest};
use galvatron::serve::{run_jsonl, serve_http, ServeState};
use galvatron::util::json::Json;

/// The serve-request twin of `persist_tests::request`: same model,
/// cluster, budget and pinned pipeline degree, so searches take
/// milliseconds and fingerprints line up with [`direct`].
fn req_line(max_batch: usize) -> String {
    format!(
        r#"{{"cluster":"titan8","max_batch":{max_batch},"memory_gb":16,"model":"bert-huge-32","pipeline_degrees":[4]}}"#
    )
}

/// The CLI-equivalent request: identical knobs, explicit thread count.
fn direct(max_batch: usize, threads: usize) -> PlanRequest {
    PlanRequest::new("bert-huge-32", "titan8")
        .memory_gb(16.0)
        .max_batch(max_batch)
        .pipeline_degrees(&[4])
        .method(MethodSpec::Bmw { ckpt: true })
        .threads(threads)
}

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("galvatron-serve-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn state(cache_dir: Option<&Path>) -> Arc<ServeState> {
    Arc::new(ServeState::new(cache_dir.map(Path::to_path_buf)))
}

fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    cond()
}

#[test]
fn concurrent_distinct_requests_match_serial_plan_artifacts() {
    // Serve plans with auto threads; the serial baseline pins threads=1.
    // Byte-identity across that asymmetry is the whole point.
    let st = state(None);
    let batches = [8usize, 12, 16, 20];
    let serial: Vec<String> = batches
        .iter()
        .map(|&b| direct(b, 1).plan().unwrap().to_json_string())
        .collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = batches
            .iter()
            .map(|&b| {
                let st = Arc::clone(&st);
                scope.spawn(move || st.handle_line(&req_line(b)))
            })
            .collect();
        for (handle, expect) in handles.into_iter().zip(&serial) {
            let outcome = handle.join().unwrap();
            assert!(outcome.ok, "{}", outcome.envelope);
            assert_eq!(
                outcome.artifact.as_deref().map(String::as_str),
                Some(expect.as_str()),
                "served artifact differs from the serial CLI artifact"
            );
            assert_eq!(
                outcome.envelope.get("cache").and_then(Json::as_str),
                Some("miss")
            );
        }
    });
    let stats = st.stats();
    assert_eq!(stats.searched, batches.len() as u64);
    assert_eq!(stats.ok, batches.len() as u64);
    assert_eq!(stats.dedup_hits, 0);
}

#[test]
fn identical_simultaneous_requests_share_one_search() {
    let st = state(None);
    let expect = direct(16, 1).plan().unwrap().to_json_string();
    let (release_tx, release_rx) = std::sync::mpsc::channel::<()>();
    std::thread::scope(|scope| {
        // Leader: registers in-flight, then blocks inside the test seam
        // until released — holding the "search" open.
        let leader = {
            let st = Arc::clone(&st);
            scope.spawn(move || {
                let v = Json::parse(&req_line(16)).unwrap();
                st.handle_value_with(&v, || {
                    release_rx.recv().unwrap();
                })
            })
        };
        assert!(
            wait_until(Duration::from_secs(10), || st.inflight_len() == 1),
            "leader never registered in-flight"
        );
        // Waiter: same request while the leader is mid-search.
        let waiter = {
            let st = Arc::clone(&st);
            scope.spawn(move || st.handle_line(&req_line(16)))
        };
        // dedup_hits is bumped before the waiter blocks on the result.
        assert!(
            wait_until(Duration::from_secs(10), || st.stats().dedup_hits == 1),
            "waiter never deduplicated onto the in-flight search"
        );
        release_tx.send(()).unwrap();
        let leader_out = leader.join().unwrap();
        let waiter_out = waiter.join().unwrap();
        assert!(leader_out.ok && waiter_out.ok);
        assert_eq!(leader_out.artifact.as_deref().map(String::as_str), Some(expect.as_str()));
        assert_eq!(waiter_out.artifact.as_deref().map(String::as_str), Some(expect.as_str()));
        assert_eq!(leader_out.envelope.get("cache").and_then(Json::as_str), Some("miss"));
        assert_eq!(waiter_out.envelope.get("cache").and_then(Json::as_str), Some("dedup"));
    });
    let stats = st.stats();
    assert_eq!(stats.searched, 1, "exactly one search for two identical requests");
    assert_eq!(stats.dedup_hits, 1);
    assert_eq!(stats.ok, 2);
    assert_eq!(st.inflight_len(), 0, "in-flight slot freed after completion");
}

#[test]
fn malformed_requests_get_typed_errors_and_the_daemon_survives() {
    let st = state(None);
    let input = format!(
        "this is not json\n{{\"model\":\"bert-huge-32\"}}\n{}\n",
        req_line(8)
    );
    let mut output: Vec<u8> = Vec::new();
    // workers=1 => responses in strict request order.
    run_jsonl(&st, std::io::Cursor::new(input.into_bytes()), &mut output, 1).unwrap();
    let text = String::from_utf8(output).unwrap();
    let lines: Vec<Json> =
        text.lines().map(|l| Json::parse(l).expect("each response line is JSON")).collect();
    assert_eq!(lines.len(), 3, "{text}");
    assert_eq!(lines[0].get("status").and_then(Json::as_str), Some("error"));
    assert_eq!(
        lines[0].get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("parse")
    );
    assert_eq!(lines[1].get("status").and_then(Json::as_str), Some("error"));
    assert_eq!(
        lines[1].get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("schema"),
        "missing cluster is a schema error"
    );
    // The daemon kept serving: the valid request after two bad ones planned.
    assert_eq!(lines[2].get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(st.stats().errors, 2);
    assert_eq!(st.stats().ok, 1);
}

#[test]
fn warm_started_daemon_answers_from_the_store_without_searching() {
    let dir = fresh_dir("warm");
    // Prime via the CLI-equivalent API path (same request fingerprint).
    let cold = direct(16, 1).cache_dir(&dir).plan().unwrap();
    // Tamper the stored throughput (persist_tests trick): if the daemon
    // returns the tampered number, it answered from the store.
    let plan_files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(Result::ok)
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("plan-") && n.ends_with(".json"))
        })
        .collect();
    assert_eq!(plan_files.len(), 1);
    let Json::Obj(mut top) = Json::parse(&std::fs::read_to_string(&plan_files[0]).unwrap())
        .unwrap()
    else {
        panic!("plan entry is not a JSON object");
    };
    match top.get_mut("report") {
        Some(Json::Obj(r)) => {
            let t = match r.get("throughput") {
                Some(Json::Num(n)) => *n,
                other => panic!("report has a numeric throughput: {other:?}"),
            };
            r.insert("throughput".to_string(), Json::num(t + 1.0));
        }
        other => panic!("plan entry has a report object: {other:?}"),
    }
    std::fs::write(&plan_files[0], Json::Obj(top).to_string()).unwrap();

    let st = state(Some(&dir));
    let first = st.handle_line(&req_line(16));
    assert!(first.ok, "{}", first.envelope);
    assert_eq!(
        first.envelope.get("cache").and_then(Json::as_str),
        Some("hit"),
        "a freshly started daemon over a primed store is warm"
    );
    let served = first
        .envelope
        .get("report")
        .and_then(|r| r.get("throughput"))
        .and_then(Json::as_f64)
        .unwrap();
    assert!(
        (served - (cold.throughput + 1.0)).abs() < 1e-6,
        "expected the stored (tampered) throughput back: served {served}, cold {}",
        cold.throughput
    );
    assert_eq!(st.stats().searched, 0, "no search may run on a warm hit");
    assert_eq!(st.stats().store_hits, 1);
    // A repeat of the same request is a memo hit — still no search.
    let second = st.handle_line(&req_line(16));
    assert!(second.ok);
    assert_eq!(second.envelope.get("cache").and_then(Json::as_str), Some("hit"));
    assert_eq!(
        second.artifact.as_deref().map(String::as_str),
        first.artifact.as_deref().map(String::as_str)
    );
    assert_eq!(st.stats().searched, 0);
    assert_eq!(st.stats().memo_hits, 1);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn invalid_store_entries_surface_as_response_warnings() {
    let dir = fresh_dir("badentry");
    direct(16, 1).cache_dir(&dir).plan().unwrap();
    // Flip the entry's fingerprint: the loader must refuse it, plan cold,
    // and the refusal must surface in the response's warnings array
    // (per-request diag capture) instead of raw stderr.
    let plan_files: Vec<PathBuf> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(Result::ok)
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("plan-") && n.ends_with(".json"))
        })
        .collect();
    assert_eq!(plan_files.len(), 1);
    let Json::Obj(mut top) = Json::parse(&std::fs::read_to_string(&plan_files[0]).unwrap())
        .unwrap()
    else {
        panic!("plan entry is not a JSON object");
    };
    top.insert("request_fingerprint".to_string(), Json::str("00000000deadbeef"));
    std::fs::write(&plan_files[0], Json::Obj(top).to_string()).unwrap();

    let st = state(Some(&dir));
    let outcome = st.handle_line(&req_line(16));
    assert!(outcome.ok, "{}", outcome.envelope);
    assert_eq!(
        outcome.envelope.get("cache").and_then(Json::as_str),
        Some("miss"),
        "a refused store entry plans cold"
    );
    let warnings = outcome.envelope.get("warnings").and_then(Json::as_arr).unwrap();
    assert!(
        warnings.iter().any(|w| {
            w.as_str().is_some_and(|s| {
                s.contains("ignoring planner cache file") && s.contains("fingerprint mismatch")
            })
        }),
        "expected the store refusal in the warnings array, got {warnings:?}"
    );
    assert_eq!(st.stats().searched, 1);
    std::fs::remove_dir_all(&dir).ok();
}

// ---- HTTP transport -------------------------------------------------------

fn http_request(
    addr: std::net::SocketAddr,
    method: &str,
    path: &str,
    body: &str,
) -> (u16, Vec<u8>) {
    let mut stream = TcpStream::connect(addr).unwrap();
    write!(
        stream,
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    stream.flush().unwrap();
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).unwrap();
    let text = String::from_utf8_lossy(&raw);
    let status: u16 = text
        .split_whitespace()
        .nth(1)
        .expect("status line")
        .parse()
        .expect("numeric status");
    let header_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("header terminator")
        + 4;
    (status, raw[header_end..].to_vec())
}

#[test]
fn http_round_trip_serves_exact_artifacts_and_typed_errors() {
    let st = state(None);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let st = Arc::clone(&st);
        // The accept loop runs forever; leak the thread (process exit
        // reaps it) exactly like the daemon would.
        std::thread::spawn(move || {
            let _ = serve_http(listener, st, 2);
        });
    }
    let expect = direct(8, 1).plan().unwrap().to_json_string();
    // Raw-artifact endpoint: byte-identical to `galvatron plan --out`.
    let (status, body) = http_request(addr, "POST", "/plan/artifact", &req_line(8));
    assert_eq!(status, 200);
    assert_eq!(body, expect.as_bytes(), "HTTP artifact differs from CLI artifact");
    // Envelope endpoint; the repeat is answered by the daemon's memo.
    let (status, body) = http_request(addr, "POST", "/plan", &req_line(8));
    assert_eq!(status, 200);
    let envelope = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(envelope.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(envelope.get("cache").and_then(Json::as_str), Some("hit"));
    // Health endpoint reports the counters.
    let (status, body) = http_request(addr, "GET", "/health", "");
    assert_eq!(status, 200);
    let health = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(health.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(
        health.get("stats").and_then(|s| s.get("searched")).and_then(Json::as_usize),
        Some(1)
    );
    // Malformed body: typed error, daemon stays up.
    let (status, body) = http_request(addr, "POST", "/plan", "not json");
    assert_eq!(status, 400);
    let envelope = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(
        envelope.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("parse")
    );
    // Unknown route.
    let (status, _) = http_request(addr, "GET", "/nope", "");
    assert_eq!(status, 404);
    // And it still serves after all that.
    let (status, body) = http_request(addr, "POST", "/plan/artifact", &req_line(8));
    assert_eq!(status, 200);
    assert_eq!(body, expect.as_bytes());
}

/// Read one HTTP response (status, `connection` header, body) off a
/// shared reader — the client side of a keep-alive conversation, where
/// read-to-EOF would block forever.
fn read_response(reader: &mut std::io::BufReader<&TcpStream>) -> (u16, String, String) {
    use std::io::BufRead;
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut content_length = 0usize;
    let mut connection = String::new();
    loop {
        let mut header = String::new();
        reader.read_line(&mut header).unwrap();
        let header = header.trim();
        if header.is_empty() {
            break;
        }
        if let Some((k, v)) = header.split_once(':') {
            if k.trim().eq_ignore_ascii_case("content-length") {
                content_length = v.trim().parse().unwrap();
            } else if k.trim().eq_ignore_ascii_case("connection") {
                connection = v.trim().to_string();
            }
        }
    }
    let mut body = vec![0u8; content_length];
    reader.read_exact(&mut body).unwrap();
    (status, connection, String::from_utf8(body).unwrap())
}

#[test]
fn keep_alive_serves_two_requests_on_one_socket() {
    let st = state(None);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let st = Arc::clone(&st);
        std::thread::spawn(move || {
            let _ = serve_http(listener, st, 2);
        });
    }
    let mut stream = TcpStream::connect(addr).unwrap();
    let send = |mut s: &TcpStream, connection: &str, body: &str| {
        write!(
            s,
            "POST /plan HTTP/1.1\r\nHost: x\r\nConnection: {connection}\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        s.flush().unwrap();
    };
    send(&stream, "keep-alive", &req_line(8));
    let mut reader = std::io::BufReader::new(&stream);
    let (status, connection, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(connection, "keep-alive", "opt-in keep-alive must be echoed");
    assert_eq!(Json::parse(&body).unwrap().get("cache").and_then(Json::as_str), Some("miss"));
    // Second request on the very same socket: served, and a memo hit.
    send(&stream, "keep-alive", &req_line(8));
    let (status, connection, body) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(connection, "keep-alive");
    assert_eq!(Json::parse(&body).unwrap().get("cache").and_then(Json::as_str), Some("hit"));
    // A request without the opt-in closes the conversation.
    send(&stream, "close", &req_line(8));
    let (status, connection, _) = read_response(&mut reader);
    assert_eq!(status, 200);
    assert_eq!(connection, "close");
    drop(reader);
    let mut rest = Vec::new();
    stream.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty(), "server must close after a non-keep-alive request");
    assert_eq!(st.stats().searched, 1, "one socket, one search, two memo hits");
}

#[test]
fn memo_capacity_bounds_entries_and_evicts_lru() {
    let st = Arc::new(ServeState::with_memo_capacity(None, 2));
    let (a, b, c) = (req_line(8), req_line(12), req_line(16));
    assert!(st.handle_line(&a).ok);
    assert!(st.handle_line(&b).ok);
    assert_eq!(st.memo_len(), 2);
    assert_eq!(st.stats().memo_evictions, 0);
    // Touch A so B becomes the least-recently-used entry...
    let again = st.handle_line(&a);
    assert_eq!(again.envelope.get("cache").and_then(Json::as_str), Some("hit"));
    // ...then C's insert at capacity evicts B, not A.
    assert!(st.handle_line(&c).ok);
    assert_eq!(st.memo_len(), 2);
    assert_eq!(st.stats().memo_evictions, 1);
    assert_eq!(st.handle_line(&a).envelope.get("cache").and_then(Json::as_str), Some("hit"));
    assert_eq!(
        st.handle_line(&b).envelope.get("cache").and_then(Json::as_str),
        Some("miss"),
        "the evicted entry must plan again"
    );
    // A, B, C cold plus B's re-plan; B's re-insert evicted C in turn.
    assert_eq!(st.stats().searched, 4);
    assert_eq!(st.stats().memo_hits, 2);
    assert_eq!(st.stats().memo_evictions, 2);
    // The bound and occupancy are visible on /health.
    let memo = st.health_json().get("memo").cloned().unwrap();
    assert_eq!(memo.get("capacity").and_then(Json::as_usize), Some(2));
    assert_eq!(memo.get("entries").and_then(Json::as_usize), Some(2));
}

#[test]
fn http_advise_endpoint_returns_a_frontier_envelope() {
    let st = state(None);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    {
        let st = Arc::clone(&st);
        std::thread::spawn(move || {
            let _ = serve_http(listener, st, 2);
        });
    }
    let req = r#"{"gpus":"RTX-TITAN-24G:2..2","max_batch":8,"model":"bert-huge-32","threads":1}"#;
    let (status, body) = http_request(addr, "POST", "/advise", req);
    assert_eq!(status, 200);
    let envelope = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(envelope.get("status").and_then(Json::as_str), Some("ok"), "{envelope}");
    let report = envelope.get("report").unwrap();
    assert_eq!(report.get("fleets_considered").and_then(Json::as_usize), Some(1));
    assert_eq!(report.get("fleets_planned").and_then(Json::as_usize), Some(1));
    assert_eq!(report.get("points").and_then(Json::as_arr).map(Vec::len), Some(1));
    // Missing "model" is a schema error, not a daemon death.
    let (status, body) = http_request(addr, "POST", "/advise", r#"{"gpus":"cpu:1..1"}"#);
    assert_eq!(status, 400);
    let envelope = Json::parse(&String::from_utf8(body).unwrap()).unwrap();
    assert_eq!(
        envelope.get("error").and_then(|e| e.get("kind")).and_then(Json::as_str),
        Some("schema")
    );
}

#[test]
fn installed_worker_budget_never_changes_artifacts() {
    // Install a tiny process-wide budget (the daemon does this at
    // startup); over-subscribed searches must still produce the exact
    // single-thread bytes. Affects only this test binary's process.
    galvatron::util::parallelism::install_worker_budget(2);
    let capped = direct(12, 8).plan().unwrap().to_json_string();
    let serial = direct(12, 1).plan().unwrap().to_json_string();
    assert_eq!(capped, serial, "worker-budget grants changed plan bytes");
}
