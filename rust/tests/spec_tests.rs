//! Integration tests for the declarative ModelSpec API (ISSUE 4):
//!   * every zoo model round-trips `spec -> JSON -> spec -> compile`
//!     bit-identically to the legacy constructor path,
//!   * zoo-via-spec planning produces byte-identical PlanReport artifacts
//!     under the default TrainConfig,
//!   * randomized ModelSpec JSON round-trip property test,
//!   * dtype/optimizer/ZeRO memory accounting end-to-end (the
//!     `--model-file gpt3-1.3b.json --cluster hetero4 --dtype bf16 --zero`
//!     acceptance scenario),
//!   * the committed `examples/models/*.json` files stay in sync with the
//!     zoo specs and compile.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::PathBuf;

use galvatron::api::{PlanError, PlanRequest, Planner};
use galvatron::model::{
    model_by_name, model_names, spec_by_name, BlockSpec, Dtype, EmbeddingSpec, Family, HeadSpec,
    ModelSpec, MoeSpec, OptimizerKind, PatchSpec, TrainConfig,
};
use galvatron::util::rng::Rng;
use galvatron::util::GIB;

fn models_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("examples").join("models")
}

fn slug(name: &str) -> String {
    name.to_ascii_lowercase().replace('/', "-")
}

#[test]
fn zoo_specs_compile_bit_identical_to_constructors() {
    for name in model_names() {
        let spec = spec_by_name(name).unwrap();
        let compiled = spec.compile().unwrap_or_else(|e| panic!("{name}: {e}"));
        let legacy = model_by_name(name).unwrap();
        assert_eq!(compiled.name, legacy.name, "{name}");
        assert_eq!(
            compiled.pre_params.to_bits(),
            legacy.pre_params.to_bits(),
            "{name}: pre_params"
        );
        assert_eq!(
            compiled.post_params.to_bits(),
            legacy.post_params.to_bits(),
            "{name}: post_params"
        );
        assert_eq!(compiled.layers.len(), legacy.layers.len(), "{name}");
        for (i, (a, b)) in compiled.layers.iter().zip(&legacy.layers).enumerate() {
            assert_eq!(a.name, b.name, "{name} layer {i}");
            assert_eq!(a.params.to_bits(), b.params.to_bits(), "{name} layer {i} params");
            assert_eq!(a.flops_fwd.to_bits(), b.flops_fwd.to_bits(), "{name} layer {i} flops");
            assert_eq!(a.act_bytes.to_bits(), b.act_bytes.to_bits(), "{name} layer {i} act");
            assert_eq!(a.bnd_bytes.to_bits(), b.bnd_bytes.to_bits(), "{name} layer {i} bnd");
            assert_eq!(
                (a.hidden, a.seq, a.heads, a.kv_seq),
                (b.hidden, b.seq, b.heads, b.kv_seq),
                "{name} layer {i} dims"
            );
        }
    }
}

#[test]
fn zoo_specs_json_round_trip() {
    for name in model_names() {
        let spec = spec_by_name(name).unwrap();
        let text = spec.to_json().to_string();
        let back = ModelSpec::from_json_str(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(back, spec, "{name}");
        assert_eq!(back.to_json().to_string(), text, "{name}: unstable serialization");
    }
}

#[test]
fn zoo_via_spec_plans_byte_identical_artifacts() {
    // The pinned guarantee of the API redesign: planning from the
    // declarative spec (inline, default TrainConfig) emits the exact
    // artifact bytes of the by-name path — the zoo-resolvable spec is not
    // recorded, so nothing in the JSON differs. The by-name request uses
    // the spec's display name (lookup is case-insensitive) so the
    // artifact's `model` string matches.
    for name in ["BERT-Huge-32", "T5-512/4-32"] {
        let by_name = PlanRequest::new(name, "titan8")
            .memory_gb(16.0)
            .max_batch(32)
            .plan()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let by_spec = PlanRequest::new("ignored", "titan8")
            .model_spec(spec_by_name(name).unwrap())
            .memory_gb(16.0)
            .max_batch(32)
            .plan()
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(by_spec.model_spec.is_none(), "{name}: zoo-equivalent spec must not be recorded");
        assert_eq!(
            by_spec.to_json_string(),
            by_name.to_json_string(),
            "{name}: spec-planned artifact differs from by-name artifact"
        );
    }
}

fn random_spec(rng: &mut Rng) -> ModelSpec {
    let family = match rng.below(4) {
        0 => Family::DecoderOnly,
        1 => Family::EncoderOnly,
        2 => Family::EncoderDecoder,
        _ => Family::Windowed,
    };
    let n_blocks = 1 + rng.below(3) as usize;
    let mut blocks = Vec::new();
    for bi in 0..n_blocks {
        let heads = 1usize << rng.below(4); // 1, 2, 4, 8
        let hidden = heads * 64 * (1 + rng.below(4) as usize);
        let seq = 32 * (1 + rng.below(8) as usize);
        let mut b = BlockSpec::dense(1 + rng.below(6) as usize, hidden, heads, seq);
        if rng.below(3) == 0 {
            b.window = Some(1 + rng.below(seq as u64) as usize);
        }
        // Decoder blocks of the encoder-decoder family carry cross
        // attention and exclude the other modifiers; make the last block
        // the decoder so the family constraint holds.
        if family == Family::EncoderDecoder && bi + 1 == n_blocks {
            b.window = None;
            b.cross_seq = Some(32 * (1 + rng.below(8) as usize));
        } else {
            if rng.below(3) == 0 {
                // A power-of-two divisor of heads (heads is a power of two).
                let mut kv = 1usize << rng.below(4);
                while kv > heads {
                    kv /= 2;
                }
                b.kv_heads = Some(kv);
            }
            if rng.below(3) == 0 {
                let experts = 2 + rng.below(7) as usize;
                b.moe = Some(MoeSpec { experts, top_k: 1 + rng.below(experts as u64) as usize });
            }
        }
        blocks.push(b);
    }
    let embedding = if rng.below(4) == 0 {
        None
    } else {
        Some(EmbeddingSpec {
            vocab: (rng.below(50000)) as usize,
            positions: (rng.below(2048)) as usize,
            patch: if rng.below(3) == 0 {
                Some(PatchSpec { channels: 3, size: 4 << rng.below(3) })
            } else {
                None
            },
            extra_params: (rng.below(10000)) as f64,
        })
    };
    let head = match rng.below(3) {
        0 => None,
        1 => Some(HeadSpec::Classifier { classes: 1 + rng.below(1000) as usize, bias: rng.below(2) == 0 }),
        _ => Some(HeadSpec::MlmVocab { vocab: 1 + rng.below(50000) as usize }),
    };
    ModelSpec { name: format!("rand-{}", rng.below(1_000_000)), family, blocks, embedding, head }
}

#[test]
fn random_specs_round_trip_through_json() {
    // Property test: any valid spec survives JSON serialization exactly,
    // and its compile is deterministic.
    let mut rng = Rng::new(0xC0FFEE);
    let mut checked = 0usize;
    while checked < 200 {
        let spec = random_spec(&mut rng);
        if spec.validate().is_err() {
            continue; // only valid specs are expected to round-trip
        }
        checked += 1;
        let text = spec.to_json().to_string();
        let back = ModelSpec::from_json_str(&text)
            .unwrap_or_else(|e| panic!("round trip failed for {text}: {e}"));
        assert_eq!(back, spec, "{text}");
        let a = spec.compile().unwrap();
        let b = back.compile().unwrap();
        assert_eq!(a.total_params().to_bits(), b.total_params().to_bits());
        assert_eq!(a.total_act_bytes().to_bits(), b.total_act_bytes().to_bits());
        assert_eq!(a.n_layers(), b.n_layers());
    }
}

#[test]
fn example_spec_files_compile_and_match_zoo() {
    let dir = models_dir();
    // Every zoo model has a committed spec file that parses back to the
    // in-tree spec AND is byte-identical to the canonical pretty format —
    // so `galvatron models --out-dir examples/models` regeneration is
    // diff-clean.
    for name in model_names() {
        let path = dir.join(format!("{}.json", slug(name)));
        let file_spec = ModelSpec::load(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let spec = spec_by_name(name).unwrap();
        assert_eq!(file_spec, spec, "{}", path.display());
        let bytes = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            bytes,
            spec.to_json().to_pretty(),
            "{}: not in canonical pretty format (regenerate with \
             `galvatron models --out-dir examples/models`)",
            path.display()
        );
    }
    // Every committed file (including non-zoo extras like gpt3-1.3b)
    // parses, validates, and compiles.
    let mut n = 0usize;
    for entry in std::fs::read_dir(&dir).expect("examples/models directory") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        n += 1;
        let spec = ModelSpec::load(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        let profile = spec.compile().unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        assert!(profile.total_params() > 0.0, "{}", path.display());
    }
    assert!(n > model_names().len(), "expected at least one non-zoo example spec");
}

#[test]
fn gpt3_1_3b_spec_file_plans_lean_on_hetero4() {
    // Acceptance: `galvatron plan --model-file examples/models/gpt3-1.3b.json
    //             --cluster hetero4 --dtype bf16 --zero` is a valid plan
    // whose simulated per-stage memory reflects the lean footprint.
    let file = models_dir().join("gpt3-1.3b.json");
    let lean = TrainConfig { dtype: Dtype::Bf16, zero: true, ..Default::default() };
    let planner = Planner::new();
    let report = PlanRequest::new("ignored", "hetero4")
        .model_file(&file)
        .train_config(lean)
        .max_batch(64)
        .plan()
        .expect("bf16+zero plan must fit hetero4");
    assert_eq!(report.model, "GPT3-1.3B");
    assert_eq!(report.train, lean);
    assert!(report.model_spec.is_some(), "non-zoo spec must be recorded in the artifact");
    report
        .plan
        .validate(24, 4)
        .expect("valid plan");

    // The artifact is self-contained: save -> load -> simulate without the
    // original file, and the simulated peaks respect per-island capacity.
    let text = report.to_json_string();
    let loaded = galvatron::api::PlanReport::from_json_str(&text).unwrap();
    assert_eq!(loaded, report);
    let sim = planner.simulate_report(&loaded).expect("simulate recorded spec");
    assert!(sim.throughput > 0.0);
    for (s, (&peak, &cap)) in sim.stage_peak_mem.iter().zip(&sim.stage_capacity).enumerate() {
        assert!(
            peak <= cap * 1.05,
            "stage {s}: peak {:.2}G exceeds capacity {:.2}G",
            peak / GIB,
            cap / GIB
        );
    }

    // Same plan re-simulated under fp32/Adam numerics uses strictly more
    // memory on every stage — the dtype/optimizer footprint is real.
    let spec = loaded.model_spec.clone().unwrap();
    let model = spec.compile().unwrap();
    let cluster = galvatron::cluster::cluster_by_name("hetero4").unwrap();
    let lean_sim = galvatron::sim::simulate_with(
        &model,
        &cluster,
        &loaded.plan,
        loaded.schedule,
        loaded.overlap_slowdown,
        lean,
    );
    let fat_sim = galvatron::sim::simulate_with(
        &model,
        &cluster,
        &loaded.plan,
        loaded.schedule,
        loaded.overlap_slowdown,
        TrainConfig::default(),
    );
    for s in 0..loaded.plan.pp {
        assert!(
            lean_sim.stage_peak_mem[s] < fat_sim.stage_peak_mem[s],
            "stage {s}: lean {:.2}G !< fp32 {:.2}G",
            lean_sim.stage_peak_mem[s] / GIB,
            fat_sim.stage_peak_mem[s] / GIB
        );
    }
}

#[test]
fn train_config_changes_are_recorded_and_round_trip() {
    let sgd = TrainConfig { optimizer: OptimizerKind::Sgd, ..Default::default() };
    let report = PlanRequest::new("bert-huge-32", "titan8")
        .memory_gb(16.0)
        .max_batch(32)
        .train_config(sgd)
        .plan()
        .expect("feasible");
    assert_eq!(report.train, sgd);
    let text = report.to_json_string();
    assert!(text.contains("\"train\""), "non-default train config must serialize: {text}");
    let back = galvatron::api::PlanReport::from_json_str(&text).unwrap();
    assert_eq!(back, report);
    // Default-config artifacts omit the key entirely (byte compat).
    let dflt = PlanRequest::new("bert-huge-32", "titan8")
        .memory_gb(16.0)
        .max_batch(32)
        .plan()
        .unwrap();
    assert!(!dflt.to_json_string().contains("\"train\""));
    assert!(!dflt.to_json_string().contains("\"model_spec\""));
}

#[test]
fn bf16_halves_dp_sdp_wire_volume() {
    // ISSUE 5 satellite: `layer_comm_volumes` was dtype-blind (hardwired
    // fp32 `params * 4.0` on the wire). Under bf16 the parameter/gradient
    // collectives (DP all-reduce, SDP gather/scatter) must shrink ~2x,
    // while the default fp32 path stays bit-identical.
    use galvatron::parallel::comm::{layer_comm_volumes, layer_comm_volumes_with};
    use galvatron::parallel::{Dim, Strategy};
    let model = model_by_name("bert-huge-32").unwrap();
    let layer = &model.layers[1];
    let bf16 = TrainConfig { dtype: Dtype::Bf16, ..Default::default() };
    for dim in [Dim::Dp, Dim::Sdp] {
        let s = Strategy::single(dim, 8, false);
        let v32 = layer_comm_volumes(layer, &s, 16.0, 0.0);
        let v16 = layer_comm_volumes_with(layer, &s, 16.0, 0.0, &bf16);
        let total32 = v32.dp_grad + v32.sdp_fwd + v32.sdp_bwd;
        let total16 = v16.dp_grad + v16.sdp_fwd + v16.sdp_bwd;
        assert!(total32 > 0.0);
        assert_eq!(total16, total32 / 2.0, "{dim:?}");
        // Default numerics delegate bit-for-bit.
        assert_eq!(
            layer_comm_volumes_with(layer, &s, 16.0, 0.0, &TrainConfig::default()),
            v32
        );
    }
    // End to end: the syncing microbatch gets cheaper under bf16 on a
    // DP-heavy plan, so estimated iteration time never regresses.
    let cluster = galvatron::cluster::cluster_by_name("titan8").unwrap();
    let est32 = galvatron::cost::CostEstimator::new(&cluster, 1, 1.3);
    let est16 = galvatron::cost::CostEstimator::new(&cluster, 1, 1.3).with_train(bf16);
    let s = Strategy::single(Dim::Dp, 8, false);
    let c32 = est32.layer_cost(layer, &s, 16.0, 0.0);
    let c16 = est16.layer_cost(layer, &s, 16.0, 0.0);
    assert!(c16.bwd_sync < c32.bwd_sync, "{} !< {}", c16.bwd_sync, c32.bwd_sync);
    assert_eq!(c16.fwd, c32.fwd);
}

#[test]
fn bad_spec_files_and_names_surface_typed_errors() {
    let dir = std::env::temp_dir();
    let path = dir.join(format!("galvatron-bad-spec-{}.json", std::process::id()));
    std::fs::write(&path, "{\"name\": \"x\"}").unwrap();
    let err = PlanRequest::new("ignored", "titan8")
        .model_file(&path)
        .plan()
        .unwrap_err();
    std::fs::remove_file(&path).ok();
    assert!(matches!(err, PlanError::InvalidModel { .. }), "{err:?}");

    // The unknown-model error hints at the .json spec-file form.
    let err = PlanRequest::new("my-own-model", "titan8").plan().unwrap_err();
    assert!(err.to_string().contains(".json"), "{err}");
}
