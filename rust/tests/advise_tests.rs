//! Integration tests for `galvatron advise`: elastic capacity planning.
//!
//! The contract under test: fleet sweeps are byte-deterministic across
//! thread counts and cache states, the reported frontier is exactly the
//! non-dominated set a brute-force sweep finds, the cheapest-at-least
//! query matches brute force, degrade replans are deterministic and reuse
//! the baseline's warm cost tables (the relaxed cost-table context: one
//! `costs-*.bin` per model/link context, not per island composition), and
//! frontier artifacts round-trip through the `check --frontier` gate.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::{Path, PathBuf};

use galvatron::advise::{
    advise, degrade, dominates, enumerate_fleets, fleet_cost_per_hour, headroom_bytes,
    parse_fleet_spec, AdviseRequest, DegradeOptions, DegradeOutcome, FrontierPoint,
};
use galvatron::api::{MethodSpec, PlanRequest};

/// The small two-class space every sweep test uses: six fleets (1x/2x of
/// each class alone, plus the two balanced mixes).
const SPACE: &str = "RTX-TITAN-24G:0..2,A100-40G:0..2";

fn fresh_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("galvatron-advise-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn costs_files(dir: &Path) -> Vec<PathBuf> {
    std::fs::read_dir(dir)
        .unwrap()
        .filter_map(Result::ok)
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("costs-") && n.ends_with(".bin"))
        })
        .collect()
}

fn sweep_request(threads: usize, cache_dir: &Path) -> AdviseRequest {
    AdviseRequest::new("bert-huge-32", parse_fleet_spec(SPACE, 2).unwrap())
        .max_batch(8)
        .threads(threads)
        .cache_dir(cache_dir)
}

#[test]
fn sweeps_are_byte_identical_across_threads_and_cache_states() {
    let dir = fresh_dir("det");
    let cold = advise(&sweep_request(1, &dir)).unwrap().to_pretty_string();
    // The relaxed cost-table context: every fleet of the sweep shares one
    // inter_bw/model context, hence exactly one cost file.
    assert_eq!(costs_files(&dir).len(), 1, "fleets must share one cost-table context");
    let warm = advise(&sweep_request(8, &dir)).unwrap().to_pretty_string();
    assert_eq!(warm, cold, "warm multi-threaded sweep changed artifact bytes");
    let dir2 = fresh_dir("det2");
    let cold2 = advise(&sweep_request(8, &dir2)).unwrap().to_pretty_string();
    assert_eq!(cold2, cold, "cold sweep in a fresh cache changed artifact bytes");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

#[test]
fn frontier_matches_brute_force_over_the_enumerated_fleets() {
    let dir = fresh_dir("brute");
    let frontier = advise(&sweep_request(1, &dir)).unwrap();
    // Brute force: plan every enumerated fleet directly with the same
    // knobs, no advise machinery.
    let fleets = enumerate_fleets(&parse_fleet_spec(SPACE, 2).unwrap());
    assert_eq!(frontier.fleets_considered, fleets.len());
    let mut feasible: Vec<FrontierPoint> = Vec::new();
    for cluster in &fleets {
        let req = PlanRequest::new("bert-huge-32", "")
            .cluster_spec(cluster.clone())
            .method(MethodSpec::Bmw { ckpt: true })
            .max_batch(8)
            .threads(1);
        let Ok(report) = req.plan() else { continue };
        feasible.push(FrontierPoint {
            cluster: cluster.name.clone(),
            devices: cluster.n_devices(),
            cost_per_hour: fleet_cost_per_hour(cluster),
            throughput: report.throughput,
            headroom_bytes: headroom_bytes(cluster, &report),
            report,
        });
    }
    assert_eq!(frontier.fleets_planned, feasible.len());
    assert!(!frontier.points.is_empty());
    // Every reported point is non-dominated against ALL feasible fleets.
    for p in &frontier.points {
        assert!(
            !feasible.iter().any(|q| dominates(q, p)),
            "frontier point '{}' is dominated by brute-force fleet '{}'",
            p.cluster,
            feasible.iter().find(|q| dominates(q, p)).unwrap().cluster
        );
    }
    // Every non-dominated feasible fleet's objective triple is on the
    // frontier (bit-exact: both sides planned the same deterministic search).
    for q in &feasible {
        if feasible.iter().any(|r| dominates(r, q)) {
            continue;
        }
        assert!(
            frontier.points.iter().any(|p| p.cluster == q.cluster
                && p.cost_per_hour == q.cost_per_hour
                && p.throughput == q.throughput
                && p.headroom_bytes == q.headroom_bytes),
            "non-dominated fleet '{}' is missing from the frontier",
            q.cluster
        );
    }
    // The cheapest-at-least query agrees with brute force on cost.
    let mut thresholds: Vec<f64> = vec![0.0];
    thresholds.extend(frontier.points.iter().map(|p| p.throughput));
    for min in thresholds {
        let brute_min = feasible
            .iter()
            .filter(|q| q.throughput >= min)
            .map(|q| q.cost_per_hour)
            .min_by(f64::total_cmp);
        assert_eq!(
            frontier.cheapest_at_least(min).map(|p| p.cost_per_hour),
            brute_min,
            "cheapest fleet >= {min} samples/s disagrees with brute force"
        );
    }
    let max = feasible.iter().map(|q| q.throughput).fold(0.0, f64::max);
    assert!(frontier.cheapest_at_least(max + 1.0).is_none());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn degrade_replans_each_shrunk_cluster_warm_and_deterministically() {
    let dir = fresh_dir("degrade");
    let base = PlanRequest::new("bert-huge-32", "hetero4")
        .max_batch(8)
        .method(MethodSpec::Bmw { ckpt: true })
        .threads(1)
        .cache_dir(&dir)
        .plan()
        .unwrap();
    assert_eq!(costs_files(&dir).len(), 1);
    let opts =
        DegradeOptions { lose: 1, threads: Some(1), cache_dir: Some(dir.clone()) };
    let first = degrade(&base, &opts).unwrap();
    assert_eq!(first.scenarios.len(), 2, "hetero4 has two islands");
    assert_eq!(first.scenarios[0].lost_islands, vec![0]);
    assert_eq!(first.scenarios[0].cluster, "2xA100-80G");
    assert_eq!(first.scenarios[1].lost_islands, vec![1]);
    assert_eq!(first.scenarios[1].cluster, "2xRTX-TITAN-24G");
    for s in &first.scenarios {
        match &s.outcome {
            DegradeOutcome::Planned { report, throughput_ratio, warm_start } => {
                assert!(report.throughput > 0.0 && *throughput_ratio > 0.0);
                // The shrunk clusters share the baseline's cost-table
                // context, so both replans start warm.
                assert!(*warm_start, "replan of '{}' rebuilt cost tables cold", s.cluster);
            }
            other => panic!("losing one hetero4 island must stay plannable: {other:?}"),
        }
    }
    // No second cost file appeared: the degraded contexts hit the
    // baseline's table instead of building their own.
    assert_eq!(costs_files(&dir).len(), 1, "degrade replans created a new cost-table context");
    // Byte-determinism of the serialized report across repeat runs (now
    // answered by the plan store) and across thread counts.
    let again = degrade(&base, &opts).unwrap();
    assert_eq!(again.to_json().to_string(), first.to_json().to_string());
    let threaded_opts =
        DegradeOptions { lose: 1, threads: Some(8), cache_dir: Some(dir.clone()) };
    let threaded = degrade(&base, &threaded_opts).unwrap();
    assert_eq!(threaded.to_json().to_string(), first.to_json().to_string());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn frontier_artifacts_pass_the_check_gate_and_tampering_is_caught() {
    // Scratch-cache path: no cache_dir on the request.
    let req = AdviseRequest::new("bert-huge-32", parse_fleet_spec("RTX-TITAN-24G:2..2", 2).unwrap())
        .max_batch(8)
        .threads(1);
    let frontier = advise(&req).unwrap();
    assert_eq!(frontier.points.len(), 1);
    let report = galvatron::check::check_frontier_text(&frontier.to_pretty_string());
    assert!(!report.has_errors(), "clean frontier flagged:\n{}", report.render());
    // A dominated duplicate (same objectives, strictly pricier) must trip
    // the GAL0041 dominance rule.
    let mut tampered = frontier.clone();
    let mut dup = tampered.points[0].clone();
    dup.cost_per_hour += 1.0;
    tampered.points.push(dup);
    let report = galvatron::check::check_frontier_text(&tampered.to_pretty_string());
    assert!(report.errors().any(|d| d.code == "GAL0041"), "{}", report.render());
}

#[test]
fn never_fits_fleets_are_pruned_without_planning() {
    // 15B params in fp32 can never fit one 24G card; the sweep must
    // record it as infeasible without touching the engine.
    let req = AdviseRequest::new("gpt3-15b", parse_fleet_spec("RTX-TITAN-24G:1..1", 1).unwrap())
        .max_batch(8)
        .threads(1);
    let frontier = advise(&req).unwrap();
    assert_eq!(frontier.fleets_considered, 1);
    assert_eq!(frontier.fleets_infeasible, 1);
    assert_eq!(frontier.fleets_planned, 0);
    assert!(frontier.points.is_empty());
    // An empty frontier is still a valid, checkable artifact.
    let report = galvatron::check::check_frontier_text(&frontier.to_pretty_string());
    assert!(!report.has_errors(), "{}", report.render());
}
