//! Heterogeneous-cluster integration tests (ISSUE 3 acceptance):
//!
//!   * on a mixed 2×A100-80G + 2×RTX-TITAN-24G cluster the planner finds a
//!     feasible strategy whose memory-heaviest pipeline stages sit on the
//!     80G islands, with every stage inside its own island's budget;
//!   * a homogeneous cluster built through the new island list produces
//!     byte-identical plan artifacts to the uniform constructor (the
//!     degenerate-case guarantee);
//!   * typed `PlanError`s (no panics) for bad island CLI input;
//!   * thread-count determinism on mixed-island clusters.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{MethodSpec, PlanError, PlanRequest, Planner};
use galvatron::cluster::{cluster_by_name, parse_islands, ClusterSpec, GpuSpec};
use galvatron::util::GIB;

#[test]
fn mixed_cluster_places_memory_heavy_stages_on_big_islands() {
    // The acceptance scenario: 2×A100-80G + 2×RTX-TITAN-24G, planned via
    // the island syntax. hetero4 equivalently lists TITAN first, so the
    // identity placement would leave the 1F1B-heavy stage 0 on 24G cards.
    let report = PlanRequest::new("bert-huge-32", "hetero4")
        .max_batch(16)
        .method(MethodSpec::Bmw { ckpt: true })
        .pipeline_degrees(&[2])
        .plan()
        .expect("feasible plan on the mixed fleet");
    assert_eq!(report.plan.pp, 2);
    let slots = report.plan.stage_slots.clone().expect("mixed cluster records placement");

    let cluster = cluster_by_name("hetero4").unwrap();
    let sites = cluster.stage_sites(2);
    let caps: Vec<f64> =
        (0..2).map(|s| sites[report.plan.slot_of(s)].gpu.mem_bytes).collect();
    // Every stage fits the island it was assigned to...
    for (s, stage) in report.stages.iter().enumerate() {
        assert!(
            stage.peak_mem_bytes <= caps[s],
            "stage {s}: {:.2}G exceeds its island's {:.2}G",
            stage.peak_mem_bytes / GIB,
            caps[s] / GIB
        );
    }
    // ...and the memory-heaviest stage sits on the largest-memory island.
    let heaviest = report
        .stages
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.peak_mem_bytes.total_cmp(&b.1.peak_mem_bytes))
        .map(|(i, _)| i)
        .unwrap();
    let max_cap = caps.iter().cloned().fold(0.0, f64::max);
    assert_eq!(
        caps[heaviest],
        max_cap,
        "memory-heaviest stage {heaviest} (peaks {:?}) must be on the 80G island (slots {slots:?})",
        report.stages.iter().map(|s| s.peak_mem_bytes / GIB).collect::<Vec<_>>()
    );
    assert_eq!(max_cap, 80.0 * GIB);
}

#[test]
fn island_syntax_request_matches_hetero_preset_shape() {
    // `--islands 2xRTX-TITAN-24G,2xA100-80G` resolves through the same
    // path as the preset and plans successfully end-to-end.
    let report = PlanRequest::new("bert-huge-32", "2xRTX-TITAN-24G,2xA100-80G")
        .max_batch(16)
        .plan()
        .expect("island-syntax cluster plans");
    assert_eq!(report.cluster, "2xRTX-TITAN-24G,2xA100-80G");
    // The artifact re-resolves its cluster by the canonical island label.
    let planner = Planner::new();
    let sim = planner.simulate_report(&report).expect("resimulates from the label");
    assert!(sim.throughput > 0.0);
    assert_eq!(sim.stage_capacity.len(), report.plan.pp);
}

#[test]
fn homogeneous_island_list_is_byte_identical_to_uniform_constructor() {
    // The degenerate-case guarantee, testable without pre-PR artifacts:
    // one island of 8 TITANs == the uniform constructor, down to the plan
    // artifact bytes (same name so the reports agree on every field).
    let uniform = ClusterSpec::new("x8", GpuSpec::titan_rtx(), 8, 8, 10.0 * GIB, 10.0 * GIB)
        .unwrap()
        .with_memory_budget(16.0 * GIB);
    let mut islands = parse_islands("8xRTX-TITAN-24G").unwrap().with_memory_budget(16.0 * GIB);
    islands.name = "x8".into();
    assert!(uniform.is_homogeneous() && islands.is_homogeneous());

    let plan_with = |cluster: ClusterSpec| {
        PlanRequest::new("bert-huge-32", "unused")
            .cluster_spec(cluster)
            .max_batch(32)
            .threads(2)
            .plan()
            .expect("feasible")
            .to_json_string()
    };
    let a = plan_with(uniform);
    let b = plan_with(islands);
    assert_eq!(a, b, "island-list construction changed the homogeneous artifact");
    // And homogeneous artifacts never carry a placement field.
    assert!(!a.contains("stage_slots"), "{a}");
}

#[test]
fn bad_island_input_is_a_typed_error_not_a_panic() {
    let err = PlanRequest::new("bert-huge-32", "2xH100,2xRTX-TITAN-24G")
        .max_batch(8)
        .plan()
        .unwrap_err();
    match err {
        PlanError::InvalidCluster { reason } => {
            assert!(reason.contains("H100"), "{reason}");
            assert!(reason.contains("known"), "diagnostic lists known classes: {reason}");
        }
        other => panic!("wrong error: {other:?}"),
    }
    // Non-power-of-two fleets diagnose instead of panicking deep in the
    // search.
    let err = PlanRequest::new("bert-huge-32", "2xA100-80G,4xRTX-TITAN-24G")
        .max_batch(8)
        .plan()
        .unwrap_err();
    assert!(matches!(err, PlanError::InvalidCluster { .. }), "{err:?}");
    // Uniform --memory on a mixed fleet is rejected with a diagnostic.
    let err = PlanRequest::new("bert-huge-32", "hetero4")
        .memory_gb(16.0)
        .max_batch(8)
        .plan()
        .unwrap_err();
    match err {
        PlanError::InvalidRequest { reason } => {
            assert!(reason.contains("heterogeneous"), "{reason}")
        }
        other => panic!("wrong error: {other:?}"),
    }
}

#[test]
fn mixed_cluster_artifact_round_trips_with_placement() {
    let report = PlanRequest::new("vit-huge-32", "hetero4")
        .max_batch(16)
        .plan()
        .expect("feasible");
    let text = report.to_json_string();
    assert!(text.contains("stage_slots"), "mixed plan must record its placement: {text}");
    let back = galvatron::api::PlanReport::from_json_str(&text).unwrap();
    assert_eq!(back, report);
    assert_eq!(back.to_json_string(), text);
    back.plan.validate(32, 4).unwrap();
}

#[test]
fn thread_count_never_changes_mixed_island_artifacts() {
    let plan_with = |threads: usize| {
        PlanRequest::new("bert-huge-32", "hetero4")
            .max_batch(16)
            .threads(threads)
            .plan()
            .expect("feasible")
            .to_json_string()
    };
    assert_eq!(plan_with(1), plan_with(8));
}
