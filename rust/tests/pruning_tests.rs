//! Cold-path pruning tests (ISSUE 10): pruning may only remove work —
//! never change an artifact.
//!
//!   * byte-identity — `prune(true)` and `prune(false)` (the
//!     `GALVATRON_NO_PRUNE=1` path) produce byte-identical `PlanReport`
//!     JSON across zoo models × {titan8, hetero4} × methods, including
//!     BMW and the fixed-partition ablations;
//!   * dominance soundness — a strategy dropped as pairwise dominated is
//!     never selected by the *unpruned* stage DP, for any stage shape or
//!     microbatch count of the sweep.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{MethodSpec, PartitionPolicy, PlanRequest};
use galvatron::cluster::cluster_by_name;
use galvatron::cost::CostEstimator;
use galvatron::model::model_by_name;
use galvatron::search::decision_tree::{candidate_strategies, dominated_candidates, SpaceOptions};
use galvatron::search::dp::{dp_search, DpInput};
use galvatron::search::engine::layer_classes;
use galvatron::search::SearchConfig;
use galvatron::util::GIB;

#[test]
fn pruned_and_unpruned_reports_are_byte_identical() {
    let methods = [
        MethodSpec::Bmw { ckpt: true },
        MethodSpec::Base { ckpt: true },
        MethodSpec::Partition(PartitionPolicy::Memory),
        MethodSpec::Partition(PartitionPolicy::Time),
    ];
    for model in ["bert-huge-32", "t5-512/4-32"] {
        for (cluster, memory_gb) in [("titan8", Some(16.0)), ("hetero4", None)] {
            for method in &methods {
                let plan_with = |prune: bool| {
                    let mut req = PlanRequest::new(model, cluster)
                        .max_batch(16)
                        .method(method.clone())
                        .prune(prune);
                    if let Some(gb) = memory_gb {
                        req = req.memory_gb(gb);
                    }
                    req.plan()
                };
                let label = format!("{model}/{cluster}/{method:?}");
                match (plan_with(true), plan_with(false)) {
                    (Ok(pruned), Ok(unpruned)) => assert_eq!(
                        pruned.to_json_string(),
                        unpruned.to_json_string(),
                        "{label}: pruning changed the artifact"
                    ),
                    (Err(pruned), Err(unpruned)) => assert_eq!(
                        pruned.to_string(),
                        unpruned.to_string(),
                        "{label}: pruning changed the failure"
                    ),
                    (Ok(_), Err(e)) => {
                        panic!("{label}: plans with pruning but fails without: {e}")
                    }
                    (Err(e), Ok(_)) => {
                        panic!("{label}: plans without pruning but fails with: {e}")
                    }
                }
            }
        }
    }
}

#[test]
fn dominated_strategies_are_never_selected_by_unpruned_dp() {
    let model = model_by_name("bert-huge-32").unwrap();
    let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
    let granularity = SearchConfig::default().granularity;
    let n = model.n_layers();
    let extras: Vec<f64> = (0..n).map(|i| model.extra_params(i)).collect();
    let classes = layer_classes(&model);
    let n_classes = classes.iter().max().map(|&c| c as usize + 1).unwrap();
    let mut reps = vec![usize::MAX; n_classes];
    for (i, &c) in classes.iter().enumerate() {
        if reps[c as usize] == usize::MAX {
            reps[c as usize] = i;
        }
    }

    let mut total_dominated = 0usize;
    for pp in [1usize, 2, 4] {
        let group = cluster.n_devices() / pp;
        let est = CostEstimator::new(&cluster, pp, 1.3);
        let catalog = candidate_strategies(group, &SpaceOptions::default());
        let stage_len = n / pp;
        for m in [pp, 2 * pp, 4 * pp] {
            let b_m = 16.0 / m as f64;
            // The same per-class rows the engine's matrix bundles hold.
            let class_costs: Vec<Vec<_>> = reps
                .iter()
                .map(|&rep| {
                    catalog
                        .iter()
                        .map(|s| est.layer_cost(&model.layers[rep], s, b_m, extras[rep]))
                        .collect()
                })
                .collect();
            let dominated = dominated_candidates(&catalog, &class_costs);
            total_dominated += dominated.iter().filter(|&&d| d).count();
            for stage in 0..pp {
                let (a, b) = (stage * stage_len, (stage + 1) * stage_len);
                let Some(res) = dp_search(&DpInput {
                    layers: &model.layers[a..b],
                    extra_params: &extras[a..b],
                    strategies: &catalog,
                    costs: &est,
                    layer_offset: a,
                    b_m,
                    microbatches: m,
                    live_mb: pp - stage,
                    mem_budget: 16.0 * GIB,
                    granularity,
                }) else {
                    continue; // stage infeasible under the budget: nothing chosen
                };
                for (l, &idx) in res.choice.iter().enumerate() {
                    assert!(
                        !dominated[idx],
                        "pp={pp} m={m} stage={stage} layer={l}: unpruned DP chose \
                         dominated candidate {} — dominance would change this plan",
                        catalog[idx]
                    );
                    assert_eq!(res.strategies[l], catalog[idx], "choice/strategy mismatch");
                }
            }
        }
    }
    // The invariant must not hold vacuously: the titan8 catalogs do
    // contain dominated candidates (level-order permutations with
    // bitwise-equal costs on a uniform island).
    assert!(total_dominated > 0, "dominance rule never fired across the sweep");
}
