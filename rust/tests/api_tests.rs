//! Integration tests for the public planning API: the `PlanRequest`
//! builder contract, the typed `MethodSpec` catalog, `PlanError`
//! suggestion quality, and the serializable `PlanReport` artifact
//! (ISSUE 1 acceptance: plan → simulate round-trips through JSON).

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{
    MethodSpec, PlanError, PlanReport, PlanRequest, Planner, PLAN_ARTIFACT_VERSION,
};
use galvatron::parallel::Dim;
use galvatron::search::baselines::{method_names, run_method};
use galvatron::util::json::Json;

fn small_request() -> PlanRequest {
    PlanRequest::new("bert-huge-32", "titan8").memory_gb(16.0).max_batch(32)
}

#[test]
fn catalog_covers_every_published_name() {
    // Every name in the historical `method_names()` list plus "Alpa" and
    // the Table V ablations resolves to a spec whose canonical name maps
    // straight back.
    let mut names: Vec<String> = method_names().iter().map(|s| s.to_string()).collect();
    names.push("Alpa".into());
    names.push("Galvatron (1F1B+Mem)".into());
    names.push("Galvatron (1F1B+Time)".into());
    for name in &names {
        let spec = MethodSpec::parse(name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(spec.canonical_name(), name);
    }
    // The catalog has no extra unreachable entries.
    assert_eq!(MethodSpec::catalog_names().len(), names.len());
}

#[test]
fn builder_plan_matches_name_shim() {
    // The typed front door and the legacy string shim are the same planner.
    let report = small_request().plan().expect("feasible");
    let model = galvatron::model::model_by_name("bert-huge-32").unwrap();
    let cluster = galvatron::cluster::cluster_by_name("titan8")
        .unwrap()
        .with_memory_budget(16.0 * galvatron::util::GIB);
    let shim = run_method("Galvatron-BMW", &model, &cluster, 32).expect("feasible");
    assert_eq!(report.plan, shim.plan);
    assert_eq!(report.throughput, shim.throughput());
}

#[test]
fn plan_report_json_round_trip_is_identical() {
    let report = small_request().plan().expect("feasible");
    let text = report.to_json_string();
    let back = PlanReport::from_json_str(&text).expect("parse back");
    assert_eq!(back, report);
    // The fields the simulate/train consumers rely on, spelled out.
    assert_eq!(back.plan, report.plan);
    assert_eq!(back.throughput, report.throughput);
    assert_eq!(back.method, MethodSpec::Bmw { ckpt: true });
    assert_eq!(back.stages.len(), report.plan.pp);
    // Serialization is deterministic (stable key order).
    assert_eq!(back.to_json_string(), text);
}

#[test]
fn plan_artifact_file_round_trip_and_simulation() {
    // The CLI pipeline: `plan --out plan.json` → `simulate --plan plan.json`
    // must report the estimated throughput stored in the artifact and
    // simulate the identical plan.
    let planner = Planner::new();
    let report = small_request().plan().expect("feasible");
    let path = std::env::temp_dir().join(format!("galvatron-api-test-{}.json", std::process::id()));
    report.save(&path).expect("save");
    let loaded = PlanReport::load(&path).expect("load");
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, report);
    let sim_orig = planner.simulate_report(&report).expect("sim original");
    let sim_loaded = planner.simulate_report(&loaded).expect("sim loaded");
    assert_eq!(sim_orig.iter_time, sim_loaded.iter_time);
    assert_eq!(sim_orig.throughput, sim_loaded.throughput);
}

#[test]
fn artifact_version_is_checked() {
    let report = small_request().plan().expect("feasible");
    let mut v = report.to_json();
    if let Json::Obj(m) = &mut v {
        m.insert("version".into(), Json::num((PLAN_ARTIFACT_VERSION + 1) as f64));
    }
    let err = PlanReport::from_json(&v).unwrap_err();
    assert!(matches!(err, PlanError::Artifact { .. }), "{err:?}");
}

#[test]
fn unknown_names_return_typed_errors_with_suggestions() {
    let err = PlanRequest::new("bert-hug-32", "titan8").plan().unwrap_err();
    match err {
        PlanError::UnknownModel { name, suggestion } => {
            assert_eq!(name, "bert-hug-32");
            assert_eq!(suggestion.as_deref(), Some("bert-huge-32"));
        }
        other => panic!("wrong error: {other:?}"),
    }

    let err = PlanRequest::new("bert-huge-32", "titen8").plan().unwrap_err();
    match err {
        PlanError::UnknownCluster { suggestion, .. } => {
            assert_eq!(suggestion.as_deref(), Some("titan8"));
        }
        other => panic!("wrong error: {other:?}"),
    }

    let err = MethodSpec::parse("Galvatron-BWM").unwrap_err();
    match err {
        PlanError::UnknownMethod { suggestion, .. } => {
            assert_eq!(suggestion.as_deref(), Some("Galvatron-BMW"));
        }
        other => panic!("wrong error: {other:?}"),
    }

    // Error text is user-facing: it names the input and the suggestion.
    let msg = PlanRequest::new("bert-hug-32", "titan8").plan().unwrap_err().to_string();
    assert!(msg.contains("bert-hug-32") && msg.contains("bert-huge-32"), "{msg}");
}

#[test]
fn infeasible_budget_is_a_typed_error() {
    let err = PlanRequest::new("bert-huge-48", "titan8")
        .memory_gb(0.5)
        .max_batch(16)
        .plan()
        .unwrap_err();
    match err {
        PlanError::Infeasible { reason } => {
            assert!(reason.contains("bert-huge-48"), "{reason}");
        }
        other => panic!("wrong error: {other:?}"),
    }
}

#[test]
fn pure_method_via_builder_produces_pure_plan() {
    let report = small_request()
        .method(MethodSpec::Pure(Dim::Sdp))
        .plan()
        .expect("sdp fits at 16G");
    assert_eq!(report.plan.pp, 1);
    assert!(report.plan.strategies.iter().all(|s| s.sdp() == 8));
    assert_eq!(report.method.canonical_name(), "FSDP/ZeRO-3 (SDP)");
}

#[test]
fn report_diagnostics_are_consistent() {
    let report = small_request().plan().expect("feasible");
    assert_eq!(report.stages.len(), report.plan.pp);
    let n_layers = report.plan.strategies.len();
    // Stage layer ranges tile the model in order.
    let mut expect_start = 0usize;
    for (i, s) in report.stages.iter().enumerate() {
        assert_eq!(s.layers.0, expect_start, "stage {i}");
        assert_eq!(s.layers.1 - s.layers.0, report.plan.partition[i]);
        assert!(s.peak_mem_bytes > 0.0 && s.peak_mem_bytes <= 16.0 * galvatron::util::GIB);
        assert!((0.0..=1.0).contains(&s.est_bubble));
        expect_start = s.layers.1;
    }
    assert_eq!(expect_start, n_layers);
    assert!(report.throughput > 0.0 && report.iter_time > 0.0);
}
