//! Golden-plan snapshot tests (ISSUE 3): commit `PlanReport` JSON
//! artifacts for three zoo configurations and assert byte-identical
//! re-generation — catching accidental search-space, cost-model or
//! serialization drift, and pinning the guarantee that homogeneous
//! clusters keep producing the pre-island planner's artifacts.
//!
//! Blessing: the first run (or `GALVATRON_BLESS=1 cargo test --test
//! golden_tests`) writes `rust/tests/golden/<case>.json`; subsequent runs
//! compare byte-for-byte. Regenerate deliberately after an intentional
//! planner change and commit the refreshed artifacts (see README
//! "Golden plan snapshots").

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::PathBuf;

use galvatron::api::{MethodSpec, PlanReport, PlanRequest};

struct GoldenCase {
    model: &'static str,
    cluster: &'static str,
    method: MethodSpec,
    memory_gb: Option<f64>,
    max_batch: usize,
    slug: &'static str,
}

fn cases() -> Vec<GoldenCase> {
    vec![
        GoldenCase {
            model: "bert-huge-32",
            cluster: "titan8",
            method: MethodSpec::Bmw { ckpt: true },
            memory_gb: Some(16.0),
            max_batch: 32,
            slug: "bert-huge-32_titan8_bmw_16g",
        },
        GoldenCase {
            model: "t5-512/4-32",
            cluster: "titan8",
            method: MethodSpec::Base { ckpt: true },
            memory_gb: Some(8.0),
            max_batch: 32,
            slug: "t5-512-4-32_titan8_base_8g",
        },
        // Mixed islands: pins the heterogeneous search space + the
        // stage_slots artifact extension.
        GoldenCase {
            model: "bert-huge-32",
            cluster: "hetero4",
            method: MethodSpec::Bmw { ckpt: true },
            memory_gb: None,
            max_batch: 16,
            slug: "bert-huge-32_hetero4_bmw",
        },
    ]
}

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

fn plan_json(case: &GoldenCase, threads: usize) -> String {
    let mut req = PlanRequest::new(case.model, case.cluster)
        .max_batch(case.max_batch)
        .method(case.method.clone())
        .threads(threads);
    if let Some(gb) = case.memory_gb {
        req = req.memory_gb(gb);
    }
    req.plan()
        .unwrap_or_else(|e| panic!("{}: {e}", case.slug))
        .to_json_string()
}

#[test]
fn golden_plan_artifacts_are_byte_stable() {
    let dir = golden_dir();
    let bless_all = std::env::var("GALVATRON_BLESS").is_ok();
    for case in cases() {
        // In-process determinism first: worker count must never change
        // the artifact bytes (homogeneous and mixed-island cases alike).
        let json = plan_json(&case, 1);
        assert_eq!(
            json,
            plan_json(&case, 8),
            "{}: thread count changed the artifact",
            case.slug
        );
        // The artifact round-trips losslessly before it becomes a golden.
        let report = PlanReport::from_json_str(&json).expect("parse back");
        assert_eq!(report.to_json_string(), json, "{}: unstable serialization", case.slug);

        let path = dir.join(format!("{}.json", case.slug));
        if bless_all || !path.exists() {
            std::fs::create_dir_all(&dir).expect("create golden dir");
            std::fs::write(&path, &json).expect("write golden");
            eprintln!("blessed golden plan {}", path.display());
        } else {
            let golden = std::fs::read_to_string(&path).expect("read golden");
            assert_eq!(
                json,
                golden,
                "{}: plan artifact drifted from {} — if the change is intentional, \
                 regenerate with GALVATRON_BLESS=1 cargo test --test golden_tests \
                 and commit the refreshed artifact",
                case.slug,
                path.display()
            );
        }
    }
}

#[test]
fn golden_artifacts_resimulate() {
    // A committed golden must stay loadable and simulatable: the artifact
    // pipeline (plan → save → load → simulate) is part of the snapshot
    // contract. Runs against freshly planned artifacts when goldens are
    // not yet blessed.
    let planner = galvatron::api::Planner::new();
    for case in cases() {
        let path = golden_dir().join(format!("{}.json", case.slug));
        let report = if path.exists() {
            PlanReport::load(&path).unwrap_or_else(|e| panic!("{}: {e}", case.slug))
        } else {
            PlanReport::from_json_str(&plan_json(&case, 1)).unwrap()
        };
        let sim = planner
            .simulate_report(&report)
            .unwrap_or_else(|e| panic!("{}: {e}", case.slug));
        assert!(sim.throughput > 0.0);
        // The DES tracker and the planner's Eq. 2 accounting differ by a
        // small schedule-dependent slack; 5% mirrors the sim memory tests.
        for (s, (&peak, &cap)) in
            sim.stage_peak_mem.iter().zip(&sim.stage_capacity).enumerate()
        {
            assert!(
                peak <= cap * 1.05,
                "{}: stage {s} peak {:.2}G exceeds capacity {:.2}G",
                case.slug,
                peak / 1e9,
                cap / 1e9
            );
        }
    }
}
