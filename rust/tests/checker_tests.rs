//! Golden diagnostic tests for `galvatron check` (the `src/check` engine).
//!
//! Each rule in the registry is pinned by one corrupted artifact: we plan
//! once, mutate the serialized JSON the way a buggy producer (or a human
//! editor) would, and assert the exact stable `GAL0xxx` code, severity,
//! and json-path the checker reports. Clean artifacts from both a
//! homogeneous and a heterogeneous cluster must come back error-free, so
//! the rules cannot rot into false positives either.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::collections::BTreeMap;
use std::sync::OnceLock;

use galvatron::api::{MethodSpec, PlanError, PlanReport, PlanRequest, Planner};
use galvatron::check::{check_model_json, check_plan_text, CheckReport, Severity};
use galvatron::model::ModelSpec;
use galvatron::util::json::Json;
use galvatron::util::GIB;

// ---- fixtures -------------------------------------------------------------

/// One real plan artifact per test binary: bert-huge-32 on titan8 with the
/// pipeline degree pinned to 4, so mutations can rely on pp=4 / group=2.
fn titan8_plan() -> &'static str {
    static PLAN: OnceLock<String> = OnceLock::new();
    PLAN.get_or_init(|| {
        PlanRequest::new("bert-huge-32", "titan8")
            .memory_gb(16.0)
            .max_batch(32)
            .pipeline_degrees(&[4])
            .method(MethodSpec::Bmw { ckpt: true })
            .plan()
            .expect("baseline titan8 plan")
            .to_json_string()
    })
}

fn hetero4_plan() -> &'static str {
    static PLAN: OnceLock<String> = OnceLock::new();
    PLAN.get_or_init(|| {
        PlanRequest::new("bert-huge-32", "hetero4")
            .max_batch(16)
            .method(MethodSpec::Bmw { ckpt: true })
            .plan()
            .expect("baseline hetero4 plan")
            .to_json_string()
    })
}

/// Parse an artifact, hand its top-level object to the closure, and
/// re-serialize. Corruptions stay valid JSON so they exercise the typed
/// rules rather than the parser.
fn mutate(base: &str, f: impl FnOnce(&mut BTreeMap<String, Json>)) -> String {
    let Json::Obj(mut top) = Json::parse(base).expect("artifact parses") else {
        panic!("artifact is not a JSON object");
    };
    f(&mut top);
    Json::Obj(top).to_string()
}

fn plan_obj(top: &mut BTreeMap<String, Json>) -> &mut BTreeMap<String, Json> {
    match top.get_mut("plan") {
        Some(Json::Obj(m)) => m,
        other => panic!("artifact has no plan object: {other:?}"),
    }
}

fn num(m: &BTreeMap<String, Json>, key: &str) -> f64 {
    match m.get(key) {
        Some(Json::Num(n)) => *n,
        other => panic!("expected number at {key}, got {other:?}"),
    }
}

fn set_num(m: &mut BTreeMap<String, Json>, key: &str, v: f64) {
    m.insert(key.to_string(), Json::num(v));
}

// ---- assertions -----------------------------------------------------------

fn assert_diag(report: &CheckReport, code: &str, severity: Severity, path: &str) {
    assert!(
        report
            .diagnostics
            .iter()
            .any(|d| d.code == code && d.severity == severity && d.path == path),
        "expected {severity}[{code}] at {path}; checker said:\n{}",
        report.render()
    );
}

fn assert_no_code(report: &CheckReport, code: &str) {
    assert!(
        report.diagnostics.iter().all(|d| d.code != code),
        "did not expect {code}; checker said:\n{}",
        report.render()
    );
}

// ---- clean artifacts pass -------------------------------------------------

#[test]
fn clean_titan8_artifact_has_no_errors() {
    let report = check_plan_text(titan8_plan());
    assert!(!report.has_errors(), "clean artifact flagged:\n{}", report.render());
}

#[test]
fn clean_hetero4_artifact_has_no_errors() {
    let report = check_plan_text(hetero4_plan());
    assert!(!report.has_errors(), "clean hetero artifact flagged:\n{}", report.render());
}

// ---- plan legality (GAL0001..GAL0007) -------------------------------------

#[test]
fn gal0001_partition_layer_coverage() {
    let text = mutate(titan8_plan(), |top| {
        match plan_obj(top).get_mut("partition") {
            Some(Json::Arr(a)) => {
                let Json::Num(n) = &mut a[0] else { panic!("partition[0] is a number") };
                *n += 1.0;
            }
            other => panic!("partition array: {other:?}"),
        }
    });
    assert_diag(&check_plan_text(&text), "GAL0001", Severity::Error, "$.plan.partition");
}

#[test]
fn gal0002_pipeline_degree_divides_devices() {
    let text = mutate(titan8_plan(), |top| set_num(plan_obj(top), "pp", 3.0));
    // titan8 has 8 devices; pp=3 does not divide them.
    assert_diag(&check_plan_text(&text), "GAL0002", Severity::Error, "$.plan.pp");
}

#[test]
fn gal0003_strategy_degree_matches_group() {
    let text = mutate(titan8_plan(), |top| {
        match plan_obj(top).get_mut("strategies") {
            // Degree 4 on a pp=4 slice of 8 devices (group size 2).
            Some(Json::Arr(a)) => a[0] = Json::str("TP2-DP2"),
            other => panic!("strategies array: {other:?}"),
        }
    });
    assert_diag(&check_plan_text(&text), "GAL0003", Severity::Error, "$.plan.strategies[0]");
}

#[test]
fn gal0004_microbatches_divide_batch() {
    // No power-of-two batch is divisible by 3.
    let text = mutate(titan8_plan(), |top| set_num(plan_obj(top), "microbatches", 3.0));
    assert_diag(&check_plan_text(&text), "GAL0004", Severity::Error, "$.plan.microbatches");
}

#[test]
fn gal0005_stage_slots_must_be_a_permutation() {
    let text = mutate(titan8_plan(), |top| {
        plan_obj(top).insert(
            "stage_slots".to_string(),
            Json::arr((0..4).map(|_| Json::num(0.0))),
        );
    });
    let report = check_plan_text(&text);
    // Slot 0 is claimed twice (first repeat is stage 1) ...
    assert_diag(&report, "GAL0005", Severity::Error, "$.plan.stage_slots[1]");
    // ... and titan8 is homogeneous, where the planner never records slots.
    assert_diag(&report, "GAL0005", Severity::Note, "$.plan.stage_slots");
}

#[test]
fn gal0006_stage_memory_rederivation() {
    // Shrink the recorded budget to 0.5 GB: every stage's re-derived peak
    // now exceeds the capacity the artifact claims it was planned under.
    let text = mutate(titan8_plan(), |top| set_num(top, "memory_budget_gb", 0.5));
    assert_diag(&check_plan_text(&text), "GAL0006", Severity::Error, "$.stages[0]");
}

#[test]
fn gal0007_memory_sandwich_violation() {
    // A maximally lopsided partition is less time-balanced than even the
    // memory-balanced partition p_m, violating the Eq. 7 side.
    let text = mutate(titan8_plan(), |top| {
        plan_obj(top).insert(
            "partition".to_string(),
            Json::arr([29, 1, 1, 1].iter().map(|&c| Json::num(f64::from(c)))),
        );
    });
    assert_diag(&check_plan_text(&text), "GAL0007", Severity::Warn, "$.plan.partition");
}

// ---- artifact consistency (GAL0010..GAL0019) ------------------------------

#[test]
fn gal0010_unknown_top_level_key() {
    let text = mutate(titan8_plan(), |top| {
        top.insert("zer0".to_string(), Json::num(1.0));
    });
    let report = check_plan_text(&text);
    assert_diag(&report, "GAL0010", Severity::Error, "$");
    // The precise unknown-key finding owns the failure; no generic parse error.
    assert_no_code(&report, "GAL0012");
}

#[test]
fn gal0011_oom_markers() {
    let report = check_plan_text("OOM\n");
    assert_diag(&report, "GAL0011", Severity::Note, "$");
    assert!(!report.has_errors(), "well-formed marker is not an error:\n{}", report.render());
    // A marker missing its newline is malformed but still recognizably OOM.
    let report = check_plan_text("OOM");
    assert_diag(&report, "GAL0011", Severity::Warn, "$");
    assert_no_code(&report, "GAL0012");
}

#[test]
fn gal0012_unparseable_artifact() {
    assert_diag(&check_plan_text("{ not json"), "GAL0012", Severity::Error, "$");
}

#[test]
fn gal0013_model_does_not_resolve() {
    let text = mutate(titan8_plan(), |top| {
        top.insert("model".to_string(), Json::str("no-such-model"));
    });
    assert_diag(&check_plan_text(&text), "GAL0013", Severity::Error, "$.model");
}

#[test]
fn gal0014_cluster_does_not_resolve() {
    let text = mutate(titan8_plan(), |top| {
        top.insert("cluster".to_string(), Json::str("no-such-cluster"));
    });
    assert_diag(&check_plan_text(&text), "GAL0014", Severity::Error, "$.cluster");
}

#[test]
fn gal0014_budget_must_be_positive() {
    let text = mutate(titan8_plan(), |top| set_num(top, "memory_budget_gb", -3.0));
    assert_diag(&check_plan_text(&text), "GAL0014", Severity::Error, "$.memory_budget_gb");
}

#[test]
fn gal0015_bogus_cost_provenance() {
    let text = mutate(titan8_plan(), |top| {
        top.insert(
            "cost_model".to_string(),
            Json::obj(vec![("backend", Json::str("bogus")), ("db_hash", Json::str("nothex"))]),
        );
    });
    let report = check_plan_text(&text);
    assert_diag(&report, "GAL0015", Severity::Error, "$.cost_model");
}

#[test]
fn gal0016_recorded_cost_drift() {
    let text = mutate(titan8_plan(), |top| {
        let t = num(top, "throughput");
        set_num(top, "throughput", t + 1.0);
    });
    assert_diag(&check_plan_text(&text), "GAL0016", Severity::Warn, "$.throughput");
}

#[test]
fn gal0017_trace_evaluation_count() {
    let text = mutate(titan8_plan(), |top| {
        match top.get_mut("search_trace") {
            Some(Json::Obj(t)) => {
                let e = num(t, "evaluations");
                set_num(t, "evaluations", e + 5.0);
            }
            other => panic!("fresh plan records a search_trace: {other:?}"),
        }
    });
    assert_diag(
        &check_plan_text(&text),
        "GAL0017",
        Severity::Warn,
        "$.search_trace.evaluations",
    );
}

#[test]
fn gal0018_batch_exceeds_max() {
    let text = mutate(titan8_plan(), |top| {
        let batch = num(plan_obj(top), "batch");
        set_num(top, "max_batch", batch - 1.0);
    });
    assert_diag(&check_plan_text(&text), "GAL0018", Severity::Error, "$.plan.batch");
}

#[test]
fn gal0019_calibrated_provenance_skips_rederivation() {
    let text = mutate(titan8_plan(), |top| {
        top.insert(
            "cost_model".to_string(),
            Json::obj(vec![
                ("backend", Json::str("calibrated")),
                ("db_hash", Json::str("0123456789abcdef")),
            ]),
        );
    });
    let report = check_plan_text(&text);
    assert_diag(&report, "GAL0019", Severity::Note, "$.cost_model");
    // Well-formed provenance: no GAL0015, and the analytic re-derivation
    // rules stand down rather than disagreeing by design.
    assert_no_code(&report, "GAL0015");
    assert_no_code(&report, "GAL0006");
    assert_no_code(&report, "GAL0016");
}

#[test]
fn gal0025_low_cache_hit_rate_on_large_search() {
    // A big sweep whose trace says most lookups missed: 20k lookups but
    // 15k distinct entries is a 25% hit rate, well under the 50% floor.
    let text = mutate(titan8_plan(), |top| {
        match top.get_mut("search_trace") {
            Some(Json::Obj(t)) => {
                set_num(t, "cache_lookups", 20_000.0);
                set_num(t, "cache_entries", 15_000.0);
            }
            other => panic!("fresh plan records a search_trace: {other:?}"),
        }
    });
    let report = check_plan_text(&text);
    assert_diag(&report, "GAL0025", Severity::Note, "$.search_trace");
    // Small searches say nothing either way: the clean pinned-pp artifact
    // is far below the lookup floor and must stay silent.
    assert_no_code(&check_plan_text(titan8_plan()), "GAL0025");
    // Nor does a large search with a healthy rate.
    let text = mutate(titan8_plan(), |top| {
        match top.get_mut("search_trace") {
            Some(Json::Obj(t)) => {
                set_num(t, "cache_lookups", 20_000.0);
                set_num(t, "cache_entries", 2_000.0);
            }
            other => panic!("fresh plan records a search_trace: {other:?}"),
        }
    });
    assert_no_code(&check_plan_text(&text), "GAL0025");
}

// ---- spec and cluster lints (GAL0020..GAL0031) ----------------------------

fn spec(s: &str) -> Json {
    Json::parse(s).expect("test spec parses")
}

#[test]
fn clean_spec_has_no_findings() {
    let v = spec(
        r#"{"name":"toy","family":"gpt",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512}]}"#,
    );
    let report = check_model_json(&v, None);
    assert!(report.diagnostics.is_empty(), "clean spec flagged:\n{}", report.render());
}

#[test]
fn gal0020_spec_with_unknown_key() {
    let v = spec(
        r#"{"name":"toy","family":"gpt","zer0":1,
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512}]}"#,
    );
    assert_diag(&check_model_json(&v, None), "GAL0020", Severity::Error, "$");
}

#[test]
fn gal0021_moe_routing_unsatisfiable() {
    let v = spec(
        r#"{"name":"toy","family":"gpt",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512,
                       "moe":{"experts":4,"top_k":5}}]}"#,
    );
    assert_diag(&check_model_json(&v, None), "GAL0021", Severity::Error, "$.blocks[0].moe");
}

#[test]
fn gal0022_kv_heads_must_divide_heads() {
    let v = spec(
        r#"{"name":"toy","family":"gpt",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512,"kv_heads":5}]}"#,
    );
    assert_diag(&check_model_json(&v, None), "GAL0022", Severity::Error, "$.blocks[0].kv_heads");
}

#[test]
fn gal0023_window_wider_than_seq() {
    let v = spec(
        r#"{"name":"toy","family":"gpt",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512,"window":4096}]}"#,
    );
    assert_diag(&check_model_json(&v, None), "GAL0023", Severity::Error, "$.blocks[0].window");
}

#[test]
fn gal0024_window_equal_to_seq_is_redundant() {
    let v = spec(
        r#"{"name":"toy","family":"gpt",
            "blocks":[{"count":2,"hidden":1024,"heads":16,"seq":512,"window":512}]}"#,
    );
    let report = check_model_json(&v, None);
    assert_diag(&report, "GAL0024", Severity::Note, "$.blocks[0].window");
    // window == seq passes ModelSpec::validate: a note, never an error.
    assert!(!report.has_errors(), "redundant window is advisory:\n{}", report.render());
}

/// ~32B-parameter decoder: far too big for cpu4 (16 GiB total), but small
/// enough that hetero4 (208 GiB total) could hold it — just not with a
/// uniform shard on the 24 GiB TITAN island.
fn big_spec() -> Json {
    spec(
        r#"{"name":"whale","family":"gpt",
            "blocks":[{"count":40,"hidden":8192,"heads":64,"seq":512}]}"#,
    )
}

#[test]
fn gal0030_model_never_fits_cluster() {
    let v = big_spec();
    let cluster = galvatron::api::resolve_cluster_name("cpu4").expect("cpu4 preset");
    // Precondition for the rule: fp32 weights alone exceed total capacity.
    let m = ModelSpec::from_json(&v).expect("spec").compile().expect("profile");
    assert!(m.total_params() * 4.0 > 16.0 * GIB, "test model sized for cpu4 overflow");
    assert_diag(
        &check_model_json(&v, Some(&cluster)),
        "GAL0030",
        Severity::Error,
        "$.cluster",
    );
}

#[test]
fn gal0031_island_cannot_hold_uniform_share() {
    let v = big_spec();
    let cluster = galvatron::api::resolve_cluster_name("hetero4").expect("hetero4 preset");
    // Preconditions: fits in aggregate (no GAL0030), but a uniform 4-way
    // shard overflows the 24 GiB TITAN island.
    let m = ModelSpec::from_json(&v).expect("spec").compile().expect("profile");
    let weights = m.total_params() * 4.0;
    assert!(weights <= 208.0 * GIB, "test model must fit hetero4 in aggregate");
    assert!(weights / 4.0 > 24.0 * GIB, "uniform shard must overflow the TITAN island");
    let report = check_model_json(&v, Some(&cluster));
    assert_diag(&report, "GAL0031", Severity::Warn, "$.cluster");
    assert_no_code(&report, "GAL0030");
}

// ---- strict artifact keys (PlanReport::from_json_str) ----------------------

#[test]
fn from_json_str_rejects_unknown_top_level_keys() {
    let text = mutate(titan8_plan(), |top| {
        top.insert("zer0".to_string(), Json::num(1.0));
    });
    match PlanReport::from_json_str(&text) {
        Err(PlanError::Artifact { reason }) => {
            assert!(reason.contains("zer0"), "reason names the key: {reason}");
            assert!(reason.contains("unknown key"), "reason says why: {reason}");
        }
        other => panic!("expected Artifact error, got {other:?}"),
    }
}

#[test]
fn artifact_without_optional_keys_still_loads() {
    // Pre-engine artifacts carry no search_trace; they must keep loading
    // (and checking clean) under the strict key set.
    let text = mutate(titan8_plan(), |top| {
        top.remove("search_trace");
        top.remove("model_spec");
    });
    let report = PlanReport::from_json_str(&text).expect("legacy artifact loads");
    assert!(report.search_trace.is_none());
    assert!(!check_plan_text(&text).has_errors());
}

// ---- the planner/simulator gate -------------------------------------------

#[test]
fn simulate_rejects_corrupted_artifact_via_gate() {
    let text = mutate(titan8_plan(), |top| {
        match plan_obj(top).get_mut("partition") {
            Some(Json::Arr(a)) => {
                let Json::Num(n) = &mut a[0] else { panic!("partition[0] is a number") };
                *n += 1.0;
            }
            other => panic!("partition array: {other:?}"),
        }
    });
    let report = PlanReport::from_json_str(&text).expect("shape-corrupt artifact still parses");
    match Planner::new().simulate_report(&report) {
        Err(PlanError::InvalidArtifact { diagnostics }) => {
            assert!(
                diagnostics.iter().any(|d| d.code == "GAL0001"),
                "gate surfaces the partition finding: {diagnostics:?}"
            );
            let msg = PlanError::InvalidArtifact { diagnostics }.to_string();
            assert!(msg.contains("invalid plan artifact"), "{msg}");
        }
        other => panic!("expected InvalidArtifact, got {other:?}"),
    }
}

#[test]
fn simulate_accepts_clean_artifact() {
    let report = PlanReport::from_json_str(titan8_plan()).expect("clean artifact loads");
    Planner::new().simulate_report(&report).expect("clean artifact simulates");
}
