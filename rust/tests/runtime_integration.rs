//! Integration tests over the PJRT runtime + coordinator, using the AOT
//! artifacts built by `make artifacts` (skipped gracefully if absent).

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::PathBuf;

use galvatron::coordinator::{Trainer, TrainerConfig};
use galvatron::runtime::{HostTensor, Runtime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn smoke_artifact_executes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest().unwrap();
    let art = rt
        .load("smoke", &man.smoke.file, man.smoke.inputs.clone(), man.smoke.outputs.clone())
        .unwrap();
    let a = HostTensor::scalar_f32(2.0);
    let x = HostTensor::F32 { shape: vec![16], data: (0..16).map(|i| i as f32).collect() };
    let y = HostTensor::F32 { shape: vec![16], data: vec![1.0; 16] };
    let out = art.run(&[a, x, y]).unwrap();
    let vals = out[0].as_f32().unwrap();
    for (i, &v) in vals.iter().enumerate() {
        assert!((v - (2.0 * i as f32 + 1.0)).abs() < 1e-6);
    }
}

#[test]
fn manifest_matches_files() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest().unwrap();
    assert_eq!(man.stages.len(), man.partition.len());
    assert_eq!(man.declared_params(), man.param_count);
    for sm in &man.stages {
        assert!(dir.join(&sm.fwd.file).exists());
        assert!(dir.join(&sm.bwd.file).exists());
        assert!(dir.join(&sm.adam.file).exists());
        let params = rt.load_params(&sm.param_file, &sm.param_shapes).unwrap();
        assert_eq!(params.len(), sm.param_names.len());
    }
}

#[test]
fn stage_forward_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let man = rt.manifest().unwrap();
    let sm = &man.stages[0];
    let art = rt
        .load("fwd0", &sm.fwd.file, sm.fwd.inputs.clone(), sm.fwd.outputs.clone())
        .unwrap();
    let mut args = rt.load_params(&sm.param_file, &sm.param_shapes).unwrap();
    let (b, s) = (man.config.microbatch, man.config.seq);
    args.push(HostTensor::I32 { shape: vec![b, s], data: vec![1; b * s] });
    let out = art.run(&args).unwrap();
    assert_eq!(out[0].shape(), &[b, s, man.config.hidden]);
    // Finite outputs.
    assert!(out[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
}

#[test]
fn training_reduces_loss_and_keeps_replicas_synced() {
    let Some(dir) = artifacts_dir() else { return };
    let mut trainer = Trainer::new(TrainerConfig {
        artifacts_dir: dir,
        steps: 12,
        dp: 2,
        microbatches: 2,
        log_every: 0,
        seed: 3,
        repeat_batch: true, // memorization mode: strong signal in 12 steps
    })
    .unwrap();
    let report = trainer.train().unwrap();
    assert_eq!(report.losses.len(), 12);
    assert!(report.losses.iter().all(|l| l.is_finite()));
    // Memorizing a fixed batch must cut the loss sharply (cf. the jax-side
    // probe: 9.06 -> <5 in 12 steps at lr=1e-3).
    let first = report.losses[0];
    let last = report.losses[11];
    assert!(last < first * 0.75, "no learning: {first} -> {last}");
    assert!(trainer.replicas_in_sync().unwrap());
}

#[test]
fn dp1_and_dp2_start_from_same_loss() {
    // The initial loss (before any update) is data-dependent only through
    // the corpus seed; dp replicas use different streams, so just check
    // both are near ln(vocab) at init.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let vocab = rt.manifest().unwrap().config.vocab as f64;
    for dp in [1usize, 2] {
        let mut t = Trainer::new(TrainerConfig {
            artifacts_dir: dir.clone(),
            steps: 1,
            dp,
            microbatches: 1,
            log_every: 0,
            seed: 11,
            repeat_batch: false,
        })
        .unwrap();
        let loss = t.train_step().unwrap();
        let expect = vocab.ln();
        assert!(
            (loss - expect).abs() / expect < 0.15,
            "dp={dp}: init loss {loss} vs ln(vocab) {expect}"
        );
    }
}
