//! Differential conformance suite (ISSUE 3): the Eq. 9 cost-model estimate
//! and the discrete-event simulator must agree within a stated tolerance
//! band for every zoo model × pipeline schedule, on both a homogeneous
//! cluster and a mixed-island cluster — the Fig. 7 relationship, checked
//! across the whole model zoo instead of one case.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{MethodSpec, PlanError, PlanRequest, Planner};
use galvatron::cost::pipeline::Schedule;
use galvatron::model::model_names;

/// Relative |est - sim| / sim band. The estimator's Eq. 9 approximates the
/// simulated schedule (Fig. 7 measures this gap at ≲12% for homogeneous
/// uniform-stage plans); heterogeneous placements and link-FIFO contention
/// widen it, so the conformance band is deliberately looser than the
/// single-case sim tests.
const TOLERANCE: f64 = 0.25;

#[test]
fn estimator_tracks_simulator_across_zoo_models_schedules_and_clusters() {
    let planner = Planner::new();
    let mut checked = 0usize;
    let mut skipped: Vec<String> = Vec::new();
    for model in model_names() {
        // (cluster, uniform budget override) — hetero4 fixes per-island
        // budgets via its GPU classes, so no override there.
        for (cluster, budget) in [("titan8", Some(16.0)), ("hetero4", None)] {
            for schedule in [Schedule::OneFOneB, Schedule::GPipe] {
                let mut req = PlanRequest::new(model, cluster)
                    .max_batch(8)
                    .method(MethodSpec::Base { ckpt: true })
                    .schedule(schedule);
                if let Some(gb) = budget {
                    req = req.memory_gb(gb);
                }
                let case = format!("{model} on {cluster} ({schedule:?})");
                match req.plan() {
                    Ok(report) => {
                        let sim = planner
                            .simulate_report(&report)
                            .unwrap_or_else(|e| panic!("{case}: simulate failed: {e}"));
                        let rel = (report.iter_time - sim.iter_time).abs() / sim.iter_time;
                        assert!(
                            rel <= TOLERANCE,
                            "{case}: est {:.4}s vs sim {:.4}s ({:.1}% > {:.0}%)",
                            report.iter_time,
                            sim.iter_time,
                            rel * 100.0,
                            TOLERANCE * 100.0
                        );
                        // The planner's memory accounting must hold in the
                        // simulator's allocation timeline too (per-stage
                        // island capacities, small DES/Eq. 2 slack).
                        for (s, (&peak, &cap)) in
                            sim.stage_peak_mem.iter().zip(&sim.stage_capacity).enumerate()
                        {
                            assert!(
                                peak <= cap * 1.05,
                                "{case}: stage {s} peak {:.2}G exceeds capacity {:.2}G",
                                peak / 1e9,
                                cap / 1e9
                            );
                        }
                        checked += 1;
                    }
                    // The big zoo models legitimately OOM on small fleets.
                    Err(PlanError::Infeasible { .. }) => skipped.push(case),
                    Err(e) => panic!("{case}: {e}"),
                }
            }
        }
    }
    // The band must actually be exercised broadly, not vacuously.
    assert!(
        checked >= 20,
        "only {checked} feasible conformance cases (skipped: {skipped:?})"
    );
}
