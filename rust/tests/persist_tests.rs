//! Integration tests for the persistent planning cache (`--cache-dir` /
//! `GALVATRON_CACHE_DIR`).
//!
//! The contract under test: the cache may only remove recomputation,
//! never change a plan. Warm artifacts must be byte-identical to cold
//! ones at any thread count; anything unreadable — corrupt bytes, a
//! version skew, a fingerprint mismatch — is ignored with a warning and
//! the planner falls back to a cold search.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use std::path::{Path, PathBuf};

use galvatron::api::{request_fingerprint, MethodSpec, PlanReport, PlanRequest, Planner};
use galvatron::util::json::Json;

/// A small pinned request (single pipeline degree, modest batch sweep) so
/// every test plans in milliseconds.
fn request(threads: usize) -> PlanRequest {
    PlanRequest::new("bert-huge-32", "titan8")
        .memory_gb(16.0)
        .max_batch(16)
        .pipeline_degrees(&[4])
        .method(MethodSpec::Bmw { ckpt: true })
        .threads(threads)
}

/// Per-test scratch cache directory, cleared on entry so reruns start cold.
fn fresh_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("galvatron-persist-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

fn files_matching(dir: &Path, prefix: &str, suffix: &str) -> Vec<PathBuf> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)
        .map(|rd| {
            rd.filter_map(Result::ok)
                .map(|e| e.path())
                .filter(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| n.starts_with(prefix) && n.ends_with(suffix))
                })
                .collect()
        })
        .unwrap_or_default();
    out.sort();
    out
}

fn cost_files(dir: &Path) -> Vec<PathBuf> {
    files_matching(dir, "costs-", ".bin")
}

fn plan_files(dir: &Path) -> Vec<PathBuf> {
    files_matching(dir, "plan-", ".json")
}

#[test]
fn warm_and_cold_artifacts_are_byte_identical_across_threads() {
    let cold = request(1).plan().unwrap().to_json_string();
    let dir = fresh_dir("identical");
    // Priming run: plans cold but writes the cost table and the artifact.
    let primed = request(1).cache_dir(&dir).plan().unwrap().to_json_string();
    assert_eq!(cold, primed, "a cache directory must not change the plan");
    assert_eq!(cost_files(&dir).len(), 1, "one cost table per context fingerprint");
    assert_eq!(plan_files(&dir).len(), 1, "one stored artifact per request fingerprint");
    // Warm runs answer from the store — at any worker-thread count.
    for threads in [1usize, 8] {
        let warm = request(threads).cache_dir(&dir).plan().unwrap().to_json_string();
        assert_eq!(cold, warm, "warm artifact differs at threads={threads}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn cost_table_warm_start_reproduces_the_cold_artifact() {
    let cold = request(1).plan().unwrap().to_json_string();
    let dir = fresh_dir("cost-only");
    request(1).cache_dir(&dir).plan().unwrap();
    for f in plan_files(&dir) {
        std::fs::remove_file(f).unwrap();
    }
    // With the stored artifact gone the planner must search again, now
    // warm-started from the persisted cost tables alone.
    let warm = request(8).cache_dir(&dir).plan().unwrap().to_json_string();
    assert_eq!(cold, warm, "cost-table warm start changed the plan");
    assert_eq!(plan_files(&dir).len(), 1, "the searched artifact is stored again");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupt_or_mismatched_cost_files_fall_back_cold() {
    let cold = request(1).plan().unwrap().to_json_string();
    let dir = fresh_dir("corrupt");
    request(1).cache_dir(&dir).plan().unwrap();
    let cost = cost_files(&dir);
    assert_eq!(cost.len(), 1);
    // Garbage bytes: not even the magic survives.
    std::fs::write(&cost[0], b"not a cost cache").unwrap();
    for f in plan_files(&dir) {
        std::fs::remove_file(f).unwrap();
    }
    let warm = request(1).cache_dir(&dir).plan().unwrap().to_json_string();
    assert_eq!(cold, warm, "corrupt cost file leaked into the plan");
    // That run flushed a valid store again; now flip the embedded context
    // fingerprint (bytes 8..16, after magic + version) — a well-formed
    // file for a *different* context must be ignored the same way.
    let mut bytes = std::fs::read(&cost[0]).unwrap();
    for b in &mut bytes[8..16] {
        *b ^= 0xff;
    }
    std::fs::write(&cost[0], &bytes).unwrap();
    for f in plan_files(&dir) {
        std::fs::remove_file(f).unwrap();
    }
    let warm = request(1).cache_dir(&dir).plan().unwrap().to_json_string();
    assert_eq!(cold, warm, "fingerprint-mismatched cost file leaked into the plan");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn request_level_hits_return_the_stored_artifact_without_searching() {
    let dir = fresh_dir("hit");
    let cold = request(1).cache_dir(&dir).plan().unwrap();
    let files = plan_files(&dir);
    assert_eq!(files.len(), 1);
    // Tamper the stored throughput: if the next plan() returns the
    // tampered number, it came from the store, not from a search.
    let text = std::fs::read_to_string(&files[0]).unwrap();
    let Json::Obj(mut top) = Json::parse(&text).unwrap() else {
        panic!("plan entry is not a JSON object");
    };
    match top.get_mut("report") {
        Some(Json::Obj(r)) => {
            let t = match r.get("throughput") {
                Some(Json::Num(n)) => *n,
                other => panic!("report has a numeric throughput: {other:?}"),
            };
            r.insert("throughput".to_string(), Json::num(t + 1.0));
        }
        other => panic!("plan entry has a report object: {other:?}"),
    }
    std::fs::write(&files[0], Json::Obj(top).to_string()).unwrap();
    let warm = request(1).cache_dir(&dir).plan().unwrap();
    assert!(
        (warm.throughput - (cold.throughput + 1.0)).abs() < 1e-6,
        "expected the stored (tampered) throughput back, got {} vs cold {}",
        warm.throughput,
        cold.throughput
    );
    // Now break the entry's fingerprint: the loader must refuse it, plan
    // cold (recovering the true throughput), and re-store the entry.
    let text = std::fs::read_to_string(&files[0]).unwrap();
    let Json::Obj(mut top) = Json::parse(&text).unwrap() else {
        panic!("plan entry is not a JSON object");
    };
    top.insert("request_fingerprint".to_string(), Json::str("00000000deadbeef"));
    std::fs::write(&files[0], Json::Obj(top).to_string()).unwrap();
    let fresh = request(1).cache_dir(&dir).plan().unwrap();
    assert!(
        (fresh.throughput - cold.throughput).abs() < 1e-6,
        "fingerprint mismatch must fall back to a cold search"
    );
    // The cold fallback re-stored a valid entry: the next run hits it.
    let again = request(1).cache_dir(&dir).plan().unwrap();
    assert_eq!(again.to_json_string(), fresh.to_json_string());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stored_plan_entry_is_versioned_and_fingerprinted() {
    let dir = fresh_dir("entry");
    let report = request(1).cache_dir(&dir).plan().unwrap();
    let files = plan_files(&dir);
    assert_eq!(files.len(), 1);
    let v = Json::parse(&std::fs::read_to_string(&files[0]).unwrap()).unwrap();
    assert_eq!(v.get("version").and_then(Json::as_usize), Some(1));
    let fp = v.get("request_fingerprint").and_then(Json::as_str).unwrap().to_string();
    assert_eq!(fp.len(), 16, "fingerprint is 16 hex digits: {fp:?}");
    assert!(fp.chars().all(|c| c.is_ascii_hexdigit()), "{fp:?}");
    // The file is named after the same fingerprint it records.
    assert_eq!(
        files[0].file_name().unwrap().to_str().unwrap(),
        format!("plan-{fp}.json")
    );
    // The embedded report round-trips to the exact artifact bytes.
    let stored = PlanReport::from_json(v.get("report").unwrap()).unwrap();
    assert_eq!(stored.to_json_string(), report.to_json_string());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn request_fingerprint_ignores_threads_but_tracks_content() {
    let p = Planner::new();
    let base = p.resolve(&request(1)).unwrap();
    // Worker threads never change the artifact, so they must not change
    // the fingerprint either — a t8 run may answer a t1 request.
    let same = p.resolve(&request(8)).unwrap();
    assert_eq!(request_fingerprint(&base), request_fingerprint(&same));
    // Anything that can change the plan changes the fingerprint.
    let bigger = p.resolve(&request(1).max_batch(32)).unwrap();
    assert_ne!(request_fingerprint(&base), request_fingerprint(&bigger));
    let tighter = p.resolve(&request(1).memory_gb(12.0)).unwrap();
    assert_ne!(request_fingerprint(&base), request_fingerprint(&tighter));
    let unpinned = p.resolve(&request(1).pipeline_degrees(&[2])).unwrap();
    assert_ne!(request_fingerprint(&base), request_fingerprint(&unpinned));
}

#[test]
fn env_var_fallback_and_request_field_precedence() {
    let p = Planner::new();
    let dir = fresh_dir("env");
    std::env::set_var("GALVATRON_CACHE_DIR", &dir);
    let r = p.resolve(&request(1)).unwrap();
    let explicit = p.resolve(&request(1).cache_dir("/elsewhere")).unwrap();
    std::env::remove_var("GALVATRON_CACHE_DIR");
    assert_eq!(r.cache_dir.as_deref(), Some(dir.as_path()));
    // An explicit request field wins over the environment.
    assert_eq!(explicit.cache_dir.as_deref(), Some(Path::new("/elsewhere")));
    // Without either, nothing is persisted.
    let none = p.resolve(&request(1)).unwrap();
    assert_eq!(none.cache_dir, None);
}
