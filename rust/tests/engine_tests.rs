//! Integration tests for the parallel memoized search engine (ISSUE 2):
//!
//!   * cache consistency — the memoized `LayerCost` equals a direct
//!     `CostEstimator` call for every catalog strategy;
//!   * determinism — `threads=1` and `threads=8` produce byte-identical
//!     `PlanReport` JSON (plan AND search trace) for two zoo models;
//!   * patience — the parallel sweep stops at the same ordered batch as a
//!     single-worker run;
//!   * artifacts — the `search_trace` field round-trips through JSON.

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{MethodSpec, PlanReport, PlanRequest};
use galvatron::cluster::cluster_by_name;
use galvatron::cost::{CostEstimator, StageCosts};
use galvatron::model::model_by_name;
use galvatron::search::decision_tree::{candidate_strategies, SpaceOptions};
use galvatron::search::engine::{layer_classes, CostCache};
use galvatron::search::{optimize_traced, SearchConfig};
use galvatron::util::GIB;

#[test]
fn memoized_layer_costs_equal_direct_estimator_for_every_catalog_strategy() {
    let model = model_by_name("bert-huge-32").unwrap();
    let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(16.0 * GIB);
    for pp in [1usize, 2, 4] {
        let group = cluster.n_devices() / pp;
        let est = CostEstimator::new(&cluster, pp, 1.3);
        let cache = CostCache::new(est.clone(), layer_classes(&model));
        let catalog = candidate_strategies(group, &SpaceOptions::default());
        // First, interior and last layer (distinct extra-params classes).
        for &i in &[0usize, 15, 31] {
            let layer = &model.layers[i];
            let extra = model.extra_params(i);
            for s in &catalog {
                for b_m in [1.0f64, 4.0, 8.0] {
                    let direct = est.layer_cost(layer, s, b_m, extra);
                    let memo = cache.layer_cost_at(i, layer, s, b_m, extra);
                    assert_eq!(direct, memo, "pp={pp} layer={i} {s} b_m={b_m}");
                    // Replay from cache: still identical.
                    assert_eq!(cache.layer_cost_at(i, layer, s, b_m, extra), direct);
                }
            }
        }
        assert!(cache.lookups() > cache.entries());
    }
}

#[test]
fn thread_count_never_changes_plan_report_json() {
    // Two zoo models; the whole artifact (plan, cost, stages, search
    // trace) must serialize byte-identically at 1 and 8 workers.
    for (model, budget, method) in [
        ("bert-huge-32", 16.0, MethodSpec::Bmw { ckpt: true }),
        ("t5-512/4-32", 16.0, MethodSpec::Base { ckpt: true }),
    ] {
        let plan_with = |threads: usize| -> String {
            PlanRequest::new(model, "titan8")
                .memory_gb(budget)
                .max_batch(32)
                .method(method.clone())
                .threads(threads)
                .plan()
                .expect("feasible")
                .to_json_string()
        };
        let t1 = plan_with(1);
        let t8 = plan_with(8);
        assert_eq!(t1, t8, "{model}: thread count changed the artifact");
        // And the artifact indeed carries a search trace.
        let report = PlanReport::from_json_str(&t1).unwrap();
        let trace = report.search_trace.expect("engine-planned artifact has a trace");
        assert!(trace.cells_explored > 0);
        assert!(trace.cache_lookups > 0);
        assert!(trace.best_cell.is_some());
    }
}

#[test]
fn patience_counts_ordered_batches_not_completion_order() {
    // A budget where the sweep finds small-batch plans then hits OOM wall:
    // the stopping batch (everything after it skipped/discarded) must be
    // identical for 1 and 8 workers even though 8 workers complete cells
    // in arbitrary order.
    let model = model_by_name("bert-huge-32").unwrap();
    let cluster = cluster_by_name("titan8").unwrap().with_memory_budget(5.0 * GIB);
    let run = |threads: usize| {
        let cfg = SearchConfig { threads: Some(threads), max_batch: 128, ..Default::default() };
        optimize_traced(&model, &cluster, &cfg)
    };
    let (b1, t1) = run(1);
    let (b8, t8) = run(8);
    assert_eq!(t1, t8);
    match (b1, b8) {
        (Some(x), Some(y)) => {
            assert_eq!(x.plan, y.plan);
            assert_eq!(x.throughput().to_bits(), y.throughput().to_bits());
        }
        (None, None) => {}
        _ => panic!("feasibility differed across thread counts"),
    }
    // Explored cells are a prefix of the batch-ordered grid.
    let explored_batches: Vec<usize> =
        t1.cells.iter().filter(|c| !c.discarded).map(|c| c.batch).collect();
    let mut sorted = explored_batches.clone();
    sorted.sort_unstable();
    assert_eq!(explored_batches, sorted, "reduction order must follow the batch sweep");
}

#[test]
fn search_trace_survives_artifact_round_trip() {
    let report = PlanRequest::new("bert-huge-32", "titan8")
        .memory_gb(16.0)
        .max_batch(32)
        .threads(2)
        .plan()
        .expect("feasible");
    let text = report.to_json_string();
    let back = PlanReport::from_json_str(&text).unwrap();
    assert_eq!(back, report);
    assert_eq!(back.search_trace, report.search_trace);
    assert_eq!(back.to_json_string(), text);
    // Pre-engine artifacts (no search_trace key) still load.
    let mut v = report.to_json();
    if let galvatron::util::json::Json::Obj(m) = &mut v {
        m.remove("search_trace");
    }
    let legacy = PlanReport::from_json(&v).expect("legacy artifact loads");
    assert_eq!(legacy.search_trace, None);
    assert_eq!(legacy.plan, report.plan);
}
