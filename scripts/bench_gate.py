#!/usr/bin/env python3
"""Planning-speed regression gate over BENCH_planning.json.

Reads the trajectory the `planning_speed_bench` bench just wrote at the
repository root and enforces two properties:

  1. Warm floor: every case's `warm_speedup` (request-level cache hit vs
     cold search) must be at least WARM_SPEEDUP_FLOOR. This is
     machine-independent — both numbers come from the same run.
  2. Regression: each case's cold `plans_per_sec` must stay above
     DROP_TOLERANCE x the committed BENCH_baseline.json number for the
     same (model, cluster, backend, threads) row. Machine-dependent, so
     the baseline must be blessed on the reference (CI) machine.

Usage:
    python3 scripts/bench_gate.py            # gate (CI)
    python3 scripts/bench_gate.py --bless    # adopt the current numbers
                                             # as BENCH_baseline.json

While BENCH_baseline.json is the committed placeholder (no blessed
numbers yet), the regression half is skipped with a notice and only the
warm floor is enforced.
"""

import json
import math
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CURRENT = ROOT / "BENCH_planning.json"
BASELINE = ROOT / "BENCH_baseline.json"

# A cold run may be up to 30% slower than the blessed baseline before the
# gate fails: CI machines are noisy, order-of-magnitude regressions are not.
DROP_TOLERANCE = 0.70
# The warm path answers from the stored artifact without searching; if it
# is not at least this much faster than the cold search, the cache broke.
WARM_SPEEDUP_FLOOR = 10.0


def row_key(row):
    try:
        return (
            row["model"],
            row["cluster"],
            row.get("backend", "analytic"),
            int(row["threads"]),
        )
    except (KeyError, TypeError, ValueError) as e:
        sys.exit(
            f"bench gate: malformed results row {row!r}: {e!r} — "
            "every row needs string 'model'/'cluster' and integer 'threads' keys"
        )


def finite_number(row, key, context):
    """A row's `key` as a finite float, or a precise sys.exit diagnostic."""
    value = row.get(key)
    if not isinstance(value, (int, float)) or isinstance(value, bool):
        sys.exit(
            f"bench gate: {context} row {row_key(row)} has no numeric "
            f"'{key}' field (got {value!r}) — re-run the bench, or re-bless "
            "the baseline if its schema is stale"
        )
    if not math.isfinite(value):
        sys.exit(
            f"bench gate: {context} row {row_key(row)} has a non-finite "
            f"'{key}' ({value!r}) — a zero or failed timing upstream; the "
            "gate cannot compare against it"
        )
    return float(value)


def load(path):
    try:
        return json.loads(path.read_text())
    except FileNotFoundError:
        sys.exit(f"bench gate: {path} not found — run `cargo bench --bench planning_speed_bench` first")
    except json.JSONDecodeError as e:
        sys.exit(f"bench gate: {path} is not valid JSON: {e}")


def bless(current):
    doc = {
        "bench": "planning_speed",
        "note": "Blessed planning-speed baseline; regenerate with `python3 scripts/bench_gate.py --bless`.",
        "results": current.get("results", []),
    }
    BASELINE.write_text(json.dumps(doc, indent=2, sort_keys=False) + "\n")
    print(f"bench gate: blessed {len(doc['results'])} rows into {BASELINE}")


def main():
    current = load(CURRENT)
    rows = current.get("results", [])
    if not rows:
        sys.exit(f"bench gate: {CURRENT} has no results")

    if "--bless" in sys.argv[1:]:
        bless(current)
        return

    failures = []

    for row in rows:
        speedup = finite_number(row, "warm_speedup", "current")
        if speedup < WARM_SPEEDUP_FLOOR:
            failures.append(
                f"{row_key(row)}: warm_speedup {speedup:.1f}x is below the "
                f"{WARM_SPEEDUP_FLOOR:.0f}x floor "
                f"(cold {row.get('plans_per_sec', 0):.2f}/s, "
                f"warm {row.get('plans_per_sec_warm', 0):.2f}/s)"
            )
        else:
            print(f"bench gate: {row_key(row)}: warm_speedup {speedup:.1f}x ok")

    baseline = load(BASELINE)
    if baseline.get("placeholder"):
        # Surface the skip loudly: as a GitHub Actions warning annotation
        # (rendered on the run summary page) and on stderr, so an unblessed
        # baseline cannot silently disable the regression half forever.
        message = (
            "gate skipped: baseline not blessed — BENCH_baseline.json is the "
            "placeholder, so only the warm-speedup floor was enforced. Bless "
            "on the reference machine with `python3 scripts/bench_gate.py "
            "--bless` and commit the file."
        )
        print(f"::warning title=bench gate::{message}")
        print(f"bench gate: WARNING: {message}", file=sys.stderr)
    else:
        by_key = {row_key(r): r for r in rows}
        for base in baseline.get("results", []):
            key = row_key(base)
            cur = by_key.get(key)
            if cur is None:
                failures.append(f"{key}: in the baseline but missing from this run")
                continue
            base_pps = finite_number(base, "plans_per_sec", "baseline")
            cur_pps = finite_number(cur, "plans_per_sec", "current")
            floor = DROP_TOLERANCE * base_pps
            if cur_pps < floor:
                failures.append(
                    f"{key}: cold {cur_pps:.2f} plans/s is below "
                    f"{floor:.2f} ({DROP_TOLERANCE:.0%} of the baseline "
                    f"{base_pps:.2f})"
                )
            else:
                print(
                    f"bench gate: {key}: cold {cur_pps:.2f} plans/s "
                    f"vs baseline {base_pps:.2f} ok"
                )

    if failures:
        print("bench gate: FAILED", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)
    print("bench gate: all checks passed")


if __name__ == "__main__":
    main()
