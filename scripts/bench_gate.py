#!/usr/bin/env python3
"""Planning-speed regression gate over BENCH_planning.json.

Reads the trajectory the `planning_speed_bench` bench just wrote at the
repository root and enforces three properties:

  1. Warm floor: every case's `warm_speedup` (request-level cache hit vs
     cold search) must be at least WARM_SPEEDUP_FLOOR. Machine-independent
     — both numbers come from the same run.
  2. Pruning floor: every homogeneous analytic case's `cold_speedup`
     (pruned vs `GALVATRON_NO_PRUNE=1` cold path, both from this run)
     must be at least COLD_SPEEDUP_FLOOR. Also machine-independent.
  3. Regression: each case's cold `plans_per_sec` must stay above
     DROP_TOLERANCE x the best value ever recorded for the same
     (model, cluster, backend, threads) row in the committed
     BENCH_history.jsonl. Machine-dependent, so history should be
     recorded on the reference (CI) machine.

After gating, the run's summary is appended as one JSON line to
BENCH_history.jsonl — the PR-over-PR planning-speed trajectory. Commit
the updated file so the next run gates against it. An empty (or absent)
history skips the regression half with a notice: the first recorded run
seeds it.

Usage:
    python3 scripts/bench_gate.py               # gate + append (CI)
    python3 scripts/bench_gate.py --check-only  # gate, don't append
"""

import json
import math
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
CURRENT = ROOT / "BENCH_planning.json"
HISTORY = ROOT / "BENCH_history.jsonl"

# A cold run may be up to 30% slower than the best recorded rate before the
# gate fails: CI machines are noisy, order-of-magnitude regressions are not.
DROP_TOLERANCE = 0.70
# The warm path answers from the stored artifact without searching; if it
# is not at least this much faster than the cold search, the cache broke.
WARM_SPEEDUP_FLOOR = 10.0
# Dominance pruning + lower-bound skips + DP bounds + the stage-DP memo
# must keep the pruned cold path at least this much faster than the
# GALVATRON_NO_PRUNE=1 path on the homogeneous analytic cases.
COLD_SPEEDUP_FLOOR = 3.0

# Keys copied from each bench row into the appended history line.
SUMMARY_KEYS = (
    "model",
    "cluster",
    "backend",
    "threads",
    "plans_per_sec",
    "plans_per_sec_warm",
    "warm_speedup",
    "plans_per_sec_noprune",
    "cold_speedup",
)


def row_key(row):
    try:
        return (
            row["model"],
            row["cluster"],
            row.get("backend", "analytic"),
            int(row["threads"]),
        )
    except (KeyError, TypeError, ValueError) as e:
        sys.exit(
            f"bench gate: malformed results row {row!r}: {e!r} — "
            "every row needs string 'model'/'cluster' and integer 'threads' keys"
        )


def finite_number(row, key, context):
    """A row's `key` as a finite float, or a precise sys.exit diagnostic."""
    value = row.get(key)
    if not isinstance(value, (int, float)) or isinstance(value, bool):
        sys.exit(
            f"bench gate: {context} row {row_key(row)} has no numeric "
            f"'{key}' field (got {value!r}) — re-run the bench, or prune "
            "stale history lines if their schema predates it"
        )
    if not math.isfinite(value):
        sys.exit(
            f"bench gate: {context} row {row_key(row)} has a non-finite "
            f"'{key}' ({value!r}) — a zero or failed timing upstream; the "
            "gate cannot compare against it"
        )
    return float(value)


def load(path):
    try:
        return json.loads(path.read_text())
    except FileNotFoundError:
        sys.exit(f"bench gate: {path} not found — run `cargo bench --bench planning_speed_bench` first")
    except json.JSONDecodeError as e:
        sys.exit(f"bench gate: {path} is not valid JSON: {e}")


def load_history():
    """All prior run summaries, oldest first. Malformed lines are fatal:
    silently skipping them would silently lower the recorded best."""
    if not HISTORY.exists():
        return []
    runs = []
    for i, line in enumerate(HISTORY.read_text().splitlines(), start=1):
        if not line.strip():
            continue
        try:
            runs.append(json.loads(line))
        except json.JSONDecodeError as e:
            sys.exit(f"bench gate: {HISTORY}:{i} is not valid JSON: {e}")
    return runs


def best_recorded(history):
    """Best cold plans/sec per row key across every recorded run."""
    best = {}
    for run in history:
        for row in run.get("rows", []):
            key = row_key(row)
            pps = finite_number(row, "plans_per_sec", "history")
            if key not in best or pps > best[key][0]:
                best[key] = (pps, row)
    return best


def append_history(rows):
    line = {
        "bench": "planning_speed",
        "rows": [{k: row[k] for k in SUMMARY_KEYS if k in row} for row in rows],
    }
    with HISTORY.open("a") as f:
        f.write(json.dumps(line, separators=(",", ":")) + "\n")
    print(f"bench gate: appended {len(line['rows'])} rows to {HISTORY.name}")


def main():
    current = load(CURRENT)
    rows = current.get("results", [])
    if not rows:
        sys.exit(f"bench gate: {CURRENT} has no results")

    failures = []

    for row in rows:
        speedup = finite_number(row, "warm_speedup", "current")
        if speedup < WARM_SPEEDUP_FLOOR:
            failures.append(
                f"{row_key(row)}: warm_speedup {speedup:.1f}x is below the "
                f"{WARM_SPEEDUP_FLOOR:.0f}x floor "
                f"(cold {row.get('plans_per_sec', 0):.2f}/s, "
                f"warm {row.get('plans_per_sec_warm', 0):.2f}/s)"
            )
        else:
            print(f"bench gate: {row_key(row)}: warm_speedup {speedup:.1f}x ok")
        # The pruning floor mirrors the in-bench assertion (titan8 analytic
        # at threads=1) so a stale bench binary cannot slip past CI.
        if (
            row.get("cluster") == "titan8"
            and row.get("backend", "analytic") == "analytic"
            and int(row.get("threads", 0)) == 1
        ):
            cold_speedup = finite_number(row, "cold_speedup", "current")
            if cold_speedup < COLD_SPEEDUP_FLOOR:
                failures.append(
                    f"{row_key(row)}: cold_speedup {cold_speedup:.1f}x (pruned vs "
                    f"no-prune) is below the {COLD_SPEEDUP_FLOOR:.0f}x floor"
                )
            else:
                print(f"bench gate: {row_key(row)}: cold_speedup {cold_speedup:.1f}x ok")

    history = load_history()
    if not history:
        print(
            "bench gate: no recorded history yet — the regression half is "
            "skipped; this run seeds BENCH_history.jsonl"
        )
    else:
        best = best_recorded(history)
        by_key = {row_key(r): r for r in rows}
        for key, (base_pps, _) in sorted(best.items()):
            cur = by_key.get(key)
            if cur is None:
                failures.append(f"{key}: recorded in history but missing from this run")
                continue
            cur_pps = finite_number(cur, "plans_per_sec", "current")
            floor = DROP_TOLERANCE * base_pps
            if cur_pps < floor:
                failures.append(
                    f"{key}: cold {cur_pps:.2f} plans/s is below "
                    f"{floor:.2f} ({DROP_TOLERANCE:.0%} of the recorded best "
                    f"{base_pps:.2f})"
                )
            else:
                print(
                    f"bench gate: {key}: cold {cur_pps:.2f} plans/s "
                    f"vs recorded best {base_pps:.2f} ok"
                )

    if failures:
        print("bench gate: FAILED", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        sys.exit(1)

    if "--check-only" not in sys.argv[1:]:
        append_history(rows)
    print("bench gate: all checks passed")


if __name__ == "__main__":
    main()
