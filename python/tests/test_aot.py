"""AOT pipeline: manifest integrity + HLO text artifacts parse and run."""
import json
import pathlib
import subprocess
import sys

import numpy as np
import pytest

ART = pathlib.Path("/tmp/galvatron_test_artifacts")


@pytest.fixture(scope="module")
def artifacts():
    if not (ART / "manifest.json").exists():
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(ART), "--preset", "tiny"],
            check=True,
            cwd=pathlib.Path(__file__).resolve().parents[1],
        )
    return ART


def test_manifest_complete(artifacts):
    man = json.loads((artifacts / "manifest.json").read_text())
    assert man["format_version"] == 1
    assert len(man["stages"]) == len(man["partition"])
    for st in man["stages"]:
        for kind in ("fwd", "bwd", "adam"):
            f = artifacts / st[kind]["file"]
            assert f.exists() and f.stat().st_size > 100
        assert len(st["param_names"]) == len(st["param_shapes"])
        pfile = artifacts / st["param_file"]
        n_floats = sum(int(np.prod(s)) for s in st["param_shapes"])
        assert pfile.stat().st_size == 4 * n_floats


def test_artifact_signatures(artifacts):
    man = json.loads((artifacts / "manifest.json").read_text())
    cfg = man["config"]
    b, s, h = cfg["microbatch"], cfg["seq"], cfg["hidden"]
    for st in man["stages"]:
        n = len(st["param_names"])
        # fwd inputs: params + x (+ targets on last stage)
        assert len(st["fwd"]["inputs"]) == n + (2 if st["last"] else 1)
        x_in = st["fwd"]["inputs"][n]
        if st["first"]:
            assert x_in == {"dtype": "i32", "shape": [b, s]}
        else:
            assert x_in == {"dtype": "f32", "shape": [b, s, h]}
        if st["last"]:
            assert st["fwd"]["outputs"] == [{"dtype": "f32", "shape": []}]
            assert st["bwd"]["outputs"][-1] == {"dtype": "f32", "shape": []}
        else:
            assert st["fwd"]["outputs"] == [{"dtype": "f32", "shape": [b, s, h]}]
        # adam: 4n+1 in, 3n out
        assert len(st["adam"]["inputs"]) == 4 * n + 1
        assert len(st["adam"]["outputs"]) == 3 * n


def test_hlo_text_parses(artifacts):
    """HLO text artifacts must contain an ENTRY computation (loadable text)."""
    for f in artifacts.glob("*.hlo.txt"):
        text = f.read_text()
        assert "ENTRY" in text and "ROOT" in text, f.name


def test_hlo_text_proto_roundtrip(artifacts):
    """HLO text must parse back into a module proto (what the Rust loader
    does via HloModuleProto::from_text_file) without losing the entry."""
    from jax._src.lib import xla_client as xc

    text = (artifacts / "smoke_axpy.hlo.txt").read_text()
    mod = xc._xla.hlo_module_from_text(text)
    proto = mod.as_serialized_hlo_module_proto()
    assert len(proto) > 50
    # Round-trip once more through text to confirm stability.
    text2 = mod.to_string()
    mod2 = xc._xla.hlo_module_from_text(text2)
    assert mod2.to_string() == text2


def test_profile_artifacts_present(artifacts):
    man = json.loads((artifacts / "manifest.json").read_text())
    assert len(man["profiles"]) >= 1
    for p in man["profiles"]:
        assert (artifacts / p["file"]).exists()
        assert p["flops_fwd"] > 0
