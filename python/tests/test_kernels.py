"""L1 correctness: Pallas kernels vs pure-jnp oracles (hypothesis-swept)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import flash_attention, layer_norm, matmul_bias_act, ref

SET = dict(deadline=None, max_examples=12, derandomize=True)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    b=st.sampled_from([1, 2]),
    h=st.sampled_from([1, 2, 4]),
    s=st.sampled_from([32, 64, 128]),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 100),
)
def test_attention_matches_ref(b, h, s, d, causal, seed):
    q = rand(seed, (b, h, s, d))
    k = rand(seed + 1, (b, h, s, d))
    v = rand(seed + 2, (b, h, s, d))
    out = flash_attention(q, k, v, causal)
    exp = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


@settings(**SET)
@given(
    block_q=st.sampled_from([16, 32, 64]),
    block_k=st.sampled_from([16, 32, 64]),
)
def test_attention_block_shape_invariance(block_q, block_k):
    """Output must not depend on the VMEM tiling choice."""
    q, k, v = (rand(i, (2, 2, 64, 32)) for i in range(3))
    out = flash_attention(q, k, v, True, None, block_q, block_k)
    exp = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


def test_attention_bf16():
    q, k, v = (rand(i, (1, 2, 64, 32), jnp.bfloat16) for i in range(3))
    out = flash_attention(q, k, v, False)
    exp = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32))
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(out.astype(jnp.float32), exp, rtol=3e-2, atol=3e-2)


def test_attention_grads_match_ref():
    q, k, v = (rand(i, (1, 2, 64, 32)) for i in range(3))

    def f_pallas(q, k, v):
        return (flash_attention(q, k, v, True) ** 2).sum()

    def f_ref(q, k, v):
        return (ref.attention_ref(q, k, v, causal=True) ** 2).sum()

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gp, gr):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


def test_attention_causality():
    """Perturbing a future key must not change earlier outputs."""
    q, k, v = (rand(i, (1, 1, 64, 16)) for i in range(3))
    out1 = flash_attention(q, k, v, True)
    k2 = k.at[0, 0, 63].add(100.0)
    v2 = v.at[0, 0, 63].add(100.0)
    out2 = flash_attention(q, k2, v2, True)
    np.testing.assert_allclose(out1[0, 0, :63], out2[0, 0, :63], rtol=1e-6, atol=1e-6)
    assert not np.allclose(out1[0, 0, 63], out2[0, 0, 63])


def test_attention_rejects_unaligned_seq():
    q, k, v = (rand(i, (1, 1, 48, 16)) for i in range(3))
    with pytest.raises(AssertionError):
        flash_attention(q, k, v, False, None, 32, 32)


# ---------------------------------------------------------------------------
# Fused FFN
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    m=st.sampled_from([32, 64, 128]),
    k=st.sampled_from([64, 128]),
    n=st.sampled_from([64, 128, 256]),
    act=st.sampled_from(["gelu", "none"]),
    seed=st.integers(0, 100),
)
def test_ffn_matches_ref(m, k, n, act, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n), scale=0.1)
    b = rand(seed + 2, (n,), scale=0.1)
    out = matmul_bias_act(x, w, b, act)
    exp = ref.matmul_bias_act_ref(x, w, b, activation=act)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


@settings(**SET)
@given(
    bm=st.sampled_from([16, 32]),
    bn=st.sampled_from([32, 64]),
    bk=st.sampled_from([32, 64]),
)
def test_ffn_block_shape_invariance(bm, bn, bk):
    x, w, b = rand(0, (64, 128)), rand(1, (128, 64), scale=0.1), rand(2, (64,))
    out = matmul_bias_act(x, w, b, "gelu", bm, bn, bk)
    exp = ref.matmul_bias_act_ref(x, w, b)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


def test_ffn_grads_match_ref():
    x, w, b = rand(0, (32, 64)), rand(1, (64, 128), scale=0.1), rand(2, (128,))

    def f_p(x, w, b):
        return matmul_bias_act(x, w, b, "gelu").sum()

    def f_r(x, w, b):
        return ref.matmul_bias_act_ref(x, w, b).sum()

    gp = jax.grad(f_p, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(x, w, b)
    for a, b_ in zip(gp, gr):
        np.testing.assert_allclose(a, b_, rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# LayerNorm
# ---------------------------------------------------------------------------

@settings(**SET)
@given(
    rows=st.sampled_from([32, 64, 128]),
    hidden=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 100),
)
def test_layernorm_matches_ref(rows, hidden, seed):
    x = rand(seed, (rows, hidden), scale=3.0)
    g = rand(seed + 1, (hidden,))
    b = rand(seed + 2, (hidden,))
    out = layer_norm(x, g, b)
    exp = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)


def test_layernorm_output_stats():
    """With unit gain / zero shift, rows are standardized."""
    x = rand(0, (64, 256), scale=7.0) + 3.0
    out = layer_norm(x, jnp.ones(256), jnp.zeros(256))
    np.testing.assert_allclose(np.mean(out, -1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.std(out, -1), 1.0, atol=1e-3)


def test_layernorm_grads_match_ref():
    x, g, b = rand(0, (32, 64), scale=2.0), rand(1, (64,)), rand(2, (64,))
    f_p = lambda *a: (layer_norm(*a) ** 2).sum()
    f_r = lambda *a: (ref.layernorm_ref(*a) ** 2).sum()
    gp = jax.grad(f_p, argnums=(0, 1, 2))(x, g, b)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(x, g, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=3e-5, atol=3e-5)
