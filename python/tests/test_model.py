"""L2 correctness: staged model vs monolithic reference, training sanity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(vocab=512, hidden=128, layers=2, heads=4, seq=64, microbatch=2)
CFG_REF = M.ModelConfig(vocab=512, hidden=128, layers=2, heads=4, seq=64, microbatch=2, use_pallas=False)


def make_stage_state(cfg, partition, seed=0):
    key = jax.random.PRNGKey(seed)
    out = []
    layer0 = 0
    for i, count in enumerate(partition):
        layers = list(range(layer0, layer0 + count))
        layer0 += count
        key, sub = jax.random.split(key)
        out.append(
            M.init_stage_params(cfg, layers, i == 0, i == len(partition) - 1, sub)
        )
    return out


def batch(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (cfg.microbatch, cfg.seq), 0, cfg.vocab)
    tgts = jnp.roll(toks, -1, axis=1)
    return toks, tgts


def test_param_count_formula():
    assert CFG.param_count() == 536_064  # cross-checked against aot output


def test_stage_shapes():
    partition = [1, 1]
    params = make_stage_state(CFG, partition)
    toks, tgts = batch(CFG)
    y = M.stage_forward(CFG, [0], True, False, params[0], toks)
    assert y.shape == (CFG.microbatch, CFG.seq, CFG.hidden)
    loss = M.stage_forward(CFG, [1], False, True, params[1], y, tgts)
    assert loss.shape == ()
    assert float(loss) > 0


def test_pallas_model_matches_ref_model():
    partition = [1, 1]
    params = make_stage_state(CFG, partition)
    toks, tgts = batch(CFG)
    loss_p = M.full_forward_loss(CFG, partition, params, toks, tgts)
    loss_r = M.full_forward_loss(CFG_REF, partition, params, toks, tgts)
    np.testing.assert_allclose(loss_p, loss_r, rtol=1e-4, atol=1e-4)


def test_staged_equals_monolithic():
    """Splitting into 1 vs 2 stages must not change the loss."""
    toks, tgts = batch(CFG_REF)
    p2 = make_stage_state(CFG_REF, [1, 1], seed=3)
    # Re-assemble the same parameters into a single stage.
    p1 = [p2[0] + p2[1]]
    loss2 = M.full_forward_loss(CFG_REF, [1, 1], p2, toks, tgts)
    loss1 = M.full_forward_loss(CFG_REF, [2], p1, toks, tgts)
    np.testing.assert_allclose(loss1, loss2, rtol=1e-5, atol=1e-5)


def test_stage_bwd_chain_matches_e2e_grad():
    """Chained stage bwd (the Rust pipeline's schedule) == jax.grad e2e."""
    cfg = CFG_REF
    partition = [1, 1]
    params = make_stage_state(cfg, partition, seed=1)
    toks, tgts = batch(cfg, seed=1)

    # Chained (what the coordinator runs): fwd0 -> bwd1 -> bwd0.
    fwd0, bwd0, _ = M.make_stage_fns(cfg, [0], True, False)
    _, bwd1, _ = M.make_stage_fns(cfg, [1], False, True)
    (y0,) = fwd0(*params[0], toks)
    out1 = bwd1(*params[1], y0, tgts)
    dx1, g1, loss = out1[0], out1[1:-1], out1[-1]
    g0 = bwd0(*params[0], toks, dx1)

    # Monolithic jax.grad over both stages.
    def lossfn(p0, p1):
        return M.full_forward_loss(cfg, partition, [p0, p1], toks, tgts)

    lval, (e0, e1) = jax.value_and_grad(lossfn, argnums=(0, 1))(params[0], params[1])
    np.testing.assert_allclose(loss, lval, rtol=1e-5, atol=1e-5)
    for a, b in zip(g0, e0):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    for a, b in zip(g1, e1):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_adam_step_sane():
    cfg = CFG_REF
    _, _, adam = M.make_stage_fns(cfg, [0], True, False)
    names = M.stage_param_names(cfg, [0], True, False)
    params = M.init_stage_params(cfg, [0], True, False, jax.random.PRNGKey(0))
    grads = [jnp.ones_like(p) for p in params]
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    out = adam(*params, *grads, *m, *v, jnp.float32(1.0))
    n = len(names)
    new_p, new_m, new_v = out[:n], out[n : 2 * n], out[2 * n :]
    # First Adam step with unit grads moves every param by ~lr.
    for p0, p1 in zip(params, new_p):
        np.testing.assert_allclose(np.asarray(p0 - p1), 1e-3, rtol=1e-3)
    for mi in new_m:
        np.testing.assert_allclose(np.asarray(mi), 0.1, rtol=1e-5)
    for vi in new_v:
        np.testing.assert_allclose(np.asarray(vi), 1e-3, rtol=1e-4)


@pytest.mark.slow
def test_training_reduces_loss():
    """A few Adam steps on a repeated batch must cut the loss sharply."""
    cfg = CFG_REF
    partition = [2]
    params = make_stage_state(cfg, partition, seed=5)[0]
    toks, tgts = batch(cfg, seed=5)
    _, bwd, adam = M.make_stage_fns(cfg, [0, 1], True, True)
    n = len(params)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    bwd_j = jax.jit(bwd)
    adam_j = jax.jit(adam)
    losses = []
    for step in range(1, 31):
        out = bwd_j(*params, toks, tgts)
        grads, loss = out[:-1], out[-1]  # first+last stage: no dx output
        losses.append(float(loss))
        upd = adam_j(*params, *grads, *m, *v, jnp.float32(step))
        params, m, v = list(upd[:n]), list(upd[n : 2 * n]), list(upd[2 * n :])
    assert losses[-1] < losses[0] * 0.5, losses


def test_even_partition():
    assert M.even_partition(4, 2) == [2, 2]
    assert M.even_partition(5, 2) == [3, 2]
    assert M.even_partition(7, 3) == [3, 2, 2]
    assert sum(M.even_partition(48, 7)) == 48


def test_gradient_accumulation_equivalence():
    """Mean of per-microbatch grads == grad of the full batch (the
    coordinator's accumulation scheme), because the loss is a token mean
    and microbatches are equally sized."""
    cfg = CFG_REF
    params = make_stage_state(cfg, [2], seed=9)[0]
    _, bwd, _ = M.make_stage_fns(cfg, [0, 1], True, True)
    toks1, tgts1 = batch(cfg, seed=10)
    toks2, tgts2 = batch(cfg, seed=11)

    out1 = bwd(*params, toks1, tgts1)
    out2 = bwd(*params, toks2, tgts2)
    g_acc = [(a + b) / 2 for a, b in zip(out1[:-1], out2[:-1])]

    big = M.ModelConfig(**{**cfg.__dict__, "microbatch": 2 * cfg.microbatch})
    _, bwd_big, _ = M.make_stage_fns(big, [0, 1], True, True)
    toks = jnp.concatenate([toks1, toks2], axis=0)
    tgts = jnp.concatenate([tgts1, tgts2], axis=0)
    out_big = bwd_big(*params, toks, tgts)

    for a, b in zip(g_acc, out_big[:-1]):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(
        (out1[-1] + out2[-1]) / 2, out_big[-1], rtol=1e-5
    )
