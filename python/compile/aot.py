"""AOT lowering: JAX (L2) -> HLO *text* artifacts + manifest for the Rust L3.

HLO text (NOT ``lowered.compiler_ir("hlo")``-proto serialization) is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version the ``xla`` crate binds) rejects;
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage (from ``python/``):
    python -m compile.aot --out ../artifacts [--preset e2e] [--stages 2] ...

Emits into --out:
    manifest.json                 description of everything below
    stage{i}_fwd.hlo.txt          stage forward
    stage{i}_bwd.hlo.txt          stage backward (recompute-based)
    stage{i}_adam.hlo.txt         stage Adam update
    stage{i}_params.bin           initial parameters (f32 LE, concatenated)
    profile_layer_h{H}.hlo.txt    single-layer fwd used for cost calibration
    smoke_axpy.hlo.txt            trivial runtime smoke-test artifact
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    """Lowered jax fn -> XLA HLO text with a tuple root (see module doc)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def tensor_desc(s) -> dict:
    dt = {jnp.float32.dtype: F32, jnp.int32.dtype: I32}[jnp.dtype(s.dtype)]
    return {"dtype": dt, "shape": list(s.shape)}


def lower_and_write(fn, arg_specs, path: pathlib.Path) -> dict:
    # keep_unused=True: the Rust runtime passes every declared input; jit's
    # default arg pruning would desynchronize the manifest signature from
    # the compiled program's parameter list.
    lowered = jax.jit(fn, keep_unused=True).lower(*arg_specs)
    text = to_hlo_text(lowered)
    path.write_text(text)
    out_specs = jax.eval_shape(fn, *arg_specs)
    if not isinstance(out_specs, tuple):
        out_specs = (out_specs,)
    return {
        "file": path.name,
        "inputs": [tensor_desc(s) for s in arg_specs],
        "outputs": [tensor_desc(s) for s in out_specs],
    }


def build_stage_artifacts(
    cfg: M.ModelConfig, partition: list[int], out: pathlib.Path, seed: int, lr: float
) -> list[dict]:
    stages = []
    layer0 = 0
    n_stages = len(partition)
    key = jax.random.PRNGKey(seed)
    for i, count in enumerate(partition):
        first, last = i == 0, i == n_stages - 1
        layers = list(range(layer0, layer0 + count))
        layer0 += count
        names = M.stage_param_names(cfg, layers, first, last)
        shapes = M.stage_param_shapes(cfg, layers, first, last)
        fwd, bwd, adam_raw = M.make_stage_fns(cfg, layers, first, last)
        adam = functools.partial(adam_raw, lr=lr)

        p_specs = [spec(s) for s in shapes]
        x_spec = spec((cfg.microbatch, cfg.seq), jnp.int32) if first else spec(
            (cfg.microbatch, cfg.seq, cfg.hidden)
        )
        dy_spec = spec((cfg.microbatch, cfg.seq, cfg.hidden))
        tgt_spec = spec((cfg.microbatch, cfg.seq), jnp.int32)

        fwd_args = [*p_specs, x_spec] + ([tgt_spec] if last else [])
        bwd_args = [*p_specs, x_spec] + ([tgt_spec] if last else [dy_spec])
        adam_args = [*p_specs, *p_specs, *p_specs, *p_specs, spec((), jnp.float32)]

        fwd_desc = lower_and_write(fwd, fwd_args, out / f"stage{i}_fwd.hlo.txt")
        bwd_desc = lower_and_write(bwd, bwd_args, out / f"stage{i}_bwd.hlo.txt")
        adam_desc = lower_and_write(adam, adam_args, out / f"stage{i}_adam.hlo.txt")

        # Initial parameters: concatenated f32 little-endian in param order.
        key, sub = jax.random.split(key)
        params = M.init_stage_params(cfg, layers, first, last, sub)
        flat = np.concatenate([np.asarray(p, np.float32).reshape(-1) for p in params])
        (out / f"stage{i}_params.bin").write_bytes(flat.astype("<f4").tobytes())

        stages.append(
            {
                "index": i,
                "first": first,
                "last": last,
                "layers": layers,
                "param_names": names,
                "param_shapes": [list(s) for s in shapes],
                "param_file": f"stage{i}_params.bin",
                "fwd": fwd_desc,
                "bwd": bwd_desc,
                "adam": adam_desc,
            }
        )
    return stages


def build_profile_artifacts(out: pathlib.Path, hiddens: list[int], seq: int, batch: int) -> list[dict]:
    """Single transformer-layer forwards for cost-model calibration."""
    descs = []
    for h in hiddens:
        cfg = M.ModelConfig(vocab=512, hidden=h, layers=1, heads=max(4, h // 64), seq=seq, microbatch=batch)
        shapes = M.layer_param_shapes(cfg)

        def layer_fwd(*args, cfg=cfg):
            params = list(args[:-1])
            x = args[-1]
            return (M._transformer_layer(cfg, params, x),)

        arg_specs = [*[spec(s) for s in shapes], spec((batch, seq, h))]
        d = lower_and_write(layer_fwd, arg_specs, out / f"profile_layer_h{h}.hlo.txt")
        d["hidden"] = h
        d["seq"] = seq
        d["batch"] = batch
        d["flops_fwd"] = int(
            batch * seq * (12 * h * h + 2 * seq * h) * 2  # qkv/proj/ffn + attn matmuls
        )
        descs.append(d)
    return descs


def build_smoke_artifact(out: pathlib.Path) -> dict:
    def axpy(a, x, y):
        return (a * x + y,)

    return lower_and_write(
        axpy, [spec((), jnp.float32), spec((16,)), spec((16,))], out / "smoke_axpy.hlo.txt"
    )


PRESETS = {
    # Fast CI-scale model: artifacts build in seconds, e2e steps are quick.
    "tiny": dict(vocab=512, hidden=128, layers=2, heads=4, seq=64, microbatch=2, stages=2),
    # Default end-to-end demo (~5M params; vocab sized so the Markov
    # structure is learnable within a few hundred fresh-data steps).
    "e2e": dict(vocab=2048, hidden=256, layers=4, heads=8, seq=128, microbatch=4, stages=2),
    # Larger configuration (~27M params) for longer runs.
    "mid": dict(vocab=16384, hidden=384, layers=6, heads=8, seq=128, microbatch=4, stages=2),
    # ~113M params, matches the "~100M transformer" e2e target; slow on CPU.
    "100m": dict(vocab=32768, hidden=640, layers=12, heads=10, seq=256, microbatch=4, stages=4),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="e2e", choices=sorted(PRESETS))
    ap.add_argument("--stages", type=int, default=None)
    ap.add_argument("--vocab", type=int, default=None)
    ap.add_argument("--hidden", type=int, default=None)
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--heads", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--kernels", default="pallas", choices=["pallas", "ref"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--lr", type=float, default=1e-3, help="Adam LR baked into the update artifact")
    ap.add_argument("--profile-hiddens", type=int, nargs="*", default=[256, 512])
    args = ap.parse_args()

    p = dict(PRESETS[args.preset])
    for k in ("vocab", "hidden", "layers", "heads", "seq", "microbatch", "stages"):
        v = getattr(args, k)
        if v is not None:
            p[k] = v
    stages = p.pop("stages")
    cfg = M.ModelConfig(use_pallas=(args.kernels == "pallas"), **p)
    partition = M.even_partition(cfg.layers, stages)

    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    stage_descs = build_stage_artifacts(cfg, partition, out, args.seed, args.lr)
    profile_descs = build_profile_artifacts(out, args.profile_hiddens, seq=128, batch=4)
    smoke_desc = build_smoke_artifact(out)

    manifest = {
        "format_version": 1,
        "preset": args.preset,
        "kernels": args.kernels,
        "config": dataclasses.asdict(cfg),
        "param_count": cfg.param_count(),
        "partition": partition,
        "adam": {"lr": args.lr, "b1": 0.9, "b2": 0.999, "eps": 1e-8},
        "stages": stage_descs,
        "profiles": profile_descs,
        "smoke": smoke_desc,
    }
    (out / "manifest.json").write_text(json.dumps(manifest, indent=2))
    n_files = len(list(out.iterdir()))
    print(
        f"wrote {n_files} artifacts to {out} "
        f"(preset={args.preset}, params={cfg.param_count():,}, partition={partition})"
    )


if __name__ == "__main__":
    main()
