"""L1 Pallas kernel: tiled (flash-style) scaled-dot-product attention.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): instead of the CUDA
threadblock tiling of the original flash attention, the HBM<->VMEM schedule
is expressed with Pallas ``BlockSpec``s — each grid step holds one
(block_q, head_dim) query tile resident in VMEM and streams
(block_k, head_dim) key/value tiles through it with an online-softmax
accumulator, which is the natural MXU/VMEM formulation.

The kernel is lowered with ``interpret=True`` (the CPU PJRT plugin cannot
execute Mosaic custom-calls); numerics are validated against
``ref.attention_ref`` by pytest/hypothesis.

The backward pass is a ``custom_vjp`` through the reference implementation,
so gradients of the AOT-lowered model are exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, sm_scale: float, block_q: int):
    """One grid step: one (block_q, d) query tile vs. all key/value tiles.

    q_ref: (block_q, d) VMEM tile; k_ref/v_ref: (seq, d) streamed source;
    o_ref: (block_q, d) output tile.
    """
    q = q_ref[...].astype(jnp.float32) * sm_scale
    seq = k_ref.shape[0]
    d = q_ref.shape[1]
    q_block_idx = pl.program_id(1)
    q_offs = q_block_idx * block_q + jax.lax.iota(jnp.int32, block_q)

    num_kb = seq // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k_tile = k_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        v_tile = v_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        s = q @ k_tile.T  # (block_q, block_k)
        if causal:
            k_offs = kb * block_k + jax.lax.iota(jnp.int32, block_k)
            mask = q_offs[:, None] >= k_offs[None, :]
            s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        # Rescale previous accumulator and fold in this tile.
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v_tile
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))
    # Rows that saw only masked entries keep l == 0; guard the divide.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[...] = (acc / l[:, None]).astype(o_ref.dtype)


def _attention_fwd_pallas(q, k, v, *, causal, sm_scale, block_q, block_k, interpret):
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, s, d)
    vf = v.reshape(b * h, s, d)
    grid = (b * h, s // block_q)
    kernel = functools.partial(
        _attn_kernel,
        block_k=block_k,
        causal=causal,
        sm_scale=sm_scale,
        block_q=block_q,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, d), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, s, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((None, s, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, d), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, s, d), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_attention(
    q,
    k,
    v,
    causal: bool = False,
    sm_scale: float | None = None,
    block_q: int = 32,
    block_k: int = 32,
    interpret: bool = True,
):
    """Tiled attention over (batch, heads, seq, head_dim) tensors.

    Forward runs the Pallas kernel; backward is the exact VJP of the
    reference implementation (standard practice for flash kernels).
    """
    scale = sm_scale if sm_scale is not None else 1.0 / (q.shape[-1] ** 0.5)
    return _attention_fwd_pallas(
        q, k, v, causal=causal, sm_scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )


def _fa_fwd(q, k, v, causal, sm_scale, block_q, block_k, interpret):
    out = flash_attention(q, k, v, causal, sm_scale, block_q, block_k, interpret)
    return out, (q, k, v)


def _fa_bwd(causal, sm_scale, block_q, block_k, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal, sm_scale=sm_scale),
        q, k, v,
    )
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)
