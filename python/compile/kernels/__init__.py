"""L1 Pallas kernels for the Galvatron-BMW reproduction.

All kernels lower with interpret=True (CPU-PJRT executable HLO) and carry
custom VJPs defined through the pure-jnp oracles in ref.py.
"""
from .attention import flash_attention
from .fused_ffn import matmul_bias_act
from .layernorm import layer_norm
from . import ref

__all__ = ["flash_attention", "matmul_bias_act", "layer_norm", "ref"]
