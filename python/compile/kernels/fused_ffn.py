"""L1 Pallas kernel: fused tiled matmul + bias + GELU (the FFN hot-spot).

MXU-shaped (block_m x block_k)@(block_k x block_n) tiles with an f32
accumulator carried through the K loop; bias add + GELU are fused onto the
output tile before it leaves VMEM, saving one full HBM round-trip of the
(m, n) intermediate — the TPU re-think of the CUDA epilogue-fusion idiom.

interpret=True for CPU-PJRT execution; oracle: ref.matmul_bias_act_ref.
Backward: custom_vjp through the reference (exact).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ffn_kernel(x_ref, w_ref, b_ref, o_ref, *, block_k: int, activation: str):
    """One grid step computes one (block_m, block_n) output tile."""
    kdim = x_ref.shape[1]
    num_kb = kdim // block_k

    def body(kb, acc):
        x_tile = x_ref[:, pl.dslice(kb * block_k, block_k)].astype(jnp.float32)
        w_tile = w_ref[pl.dslice(kb * block_k, block_k), :].astype(jnp.float32)
        return acc + x_tile @ w_tile

    acc0 = jnp.zeros((x_ref.shape[0], w_ref.shape[1]), jnp.float32)
    acc = jax.lax.fori_loop(0, num_kb, body, acc0)
    acc = acc + b_ref[...].astype(jnp.float32)[None, :]
    if activation == "gelu":
        acc = ref.gelu_ref(acc)
    o_ref[...] = acc.astype(o_ref.dtype)


def _ffn_fwd_pallas(x, w, b, *, activation, block_m, block_n, block_k, interpret):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (m, n, k)
    grid = (m // block_m, n // block_n)
    kernel = functools.partial(_ffn_kernel, block_k=block_k, activation=activation)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda mi, ni: (mi, 0)),
            pl.BlockSpec((k, block_n), lambda mi, ni: (0, ni)),
            pl.BlockSpec((block_n,), lambda mi, ni: (ni,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda mi, ni: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def matmul_bias_act(
    x,
    w,
    b,
    activation: str = "gelu",
    block_m: int = 32,
    block_n: int = 64,
    block_k: int = 64,
    interpret: bool = True,
):
    """Fused x @ w + b (+ GELU). x: (m, k), w: (k, n), b: (n,)."""
    return _ffn_fwd_pallas(
        x, w, b, activation=activation,
        block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret,
    )


def _ffn_vjp_fwd(x, w, b, activation, block_m, block_n, block_k, interpret):
    out = matmul_bias_act(x, w, b, activation, block_m, block_n, block_k, interpret)
    return out, (x, w, b)


def _ffn_vjp_bwd(activation, block_m, block_n, block_k, interpret, res, g):
    x, w, b = res
    _, vjp = jax.vjp(
        lambda x_, w_, b_: ref.matmul_bias_act_ref(x_, w_, b_, activation=activation),
        x, w, b,
    )
    return vjp(g)


matmul_bias_act.defvjp(_ffn_vjp_fwd, _ffn_vjp_bwd)
