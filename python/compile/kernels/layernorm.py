"""L1 Pallas kernel: row-tiled layer normalization.

Each grid step normalizes a (block_rows, hidden) tile entirely in VMEM:
mean/variance reductions stay on-chip and the scale/shift epilogue is fused.
interpret=True; oracle: ref.layernorm_ref; backward via custom_vjp through
the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    y = y * g_ref[...].astype(jnp.float32)[None, :] + b_ref[...].astype(jnp.float32)[None, :]
    o_ref[...] = y.astype(o_ref.dtype)


def _ln_fwd_pallas(x, gamma, beta, *, eps, block_rows, interpret):
    rows, hidden = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    kernel = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, hidden), lambda r: (r, 0)),
            pl.BlockSpec((hidden,), lambda r: (0,)),
            pl.BlockSpec((hidden,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, hidden), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, hidden), x.dtype),
        interpret=interpret,
    )(x, gamma, beta)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def layer_norm(x, gamma, beta, eps: float = 1e-5, block_rows: int = 32, interpret: bool = True):
    """LayerNorm over the last axis. x: (rows, hidden)."""
    return _ln_fwd_pallas(x, gamma, beta, eps=eps, block_rows=block_rows, interpret=interpret)


def _ln_vjp_fwd(x, gamma, beta, eps, block_rows, interpret):
    out = layer_norm(x, gamma, beta, eps, block_rows, interpret)
    return out, (x, gamma, beta)


def _ln_vjp_bwd(eps, block_rows, interpret, res, g):
    x, gamma, beta = res
    _, vjp = jax.vjp(lambda x_, g_, b_: ref.layernorm_ref(x_, g_, b_, eps=eps), x, gamma, beta)
    return vjp(g)


layer_norm.defvjp(_ln_vjp_fwd, _ln_vjp_bwd)
