"""Pure-jnp correctness oracles for the Pallas kernels (L1).

Every kernel in this package has an exact reference here; pytest/hypothesis
assert allclose between the Pallas output (interpret=True) and these, and
the kernels' custom VJPs are defined *through* these references so that
autodiff through the AOT-lowered model is mathematically identical to the
reference model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    sm_scale: float | None = None,
) -> jax.Array:
    """Reference scaled-dot-product attention.

    Shapes: q, k, v are (batch, heads, seq, head_dim); returns same shape.
    """
    *_, seq, head_dim = q.shape
    scale = sm_scale if sm_scale is not None else 1.0 / (head_dim**0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((seq, seq), dtype=bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    weights = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", weights, v)


def gelu_ref(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU (matches the fused FFN kernel)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x * x * x)))


def matmul_bias_act_ref(
    x: jax.Array, w: jax.Array, b: jax.Array, *, activation: str = "gelu"
) -> jax.Array:
    """Reference fused matmul + bias + activation.

    x: (m, k), w: (k, n), b: (n,) -> (m, n).
    """
    y = x @ w + b[None, :]
    if activation == "gelu":
        return gelu_ref(y)
    if activation == "none":
        return y
    raise ValueError(f"unknown activation {activation!r}")


def layernorm_ref(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5
) -> jax.Array:
    """Reference layer norm over the last axis. x: (rows, hidden)."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + eps) * gamma[None, :] + beta[None, :]


def softmax_xent_ref(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy. logits: (n, vocab), targets: (n,) int32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, targets[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)
