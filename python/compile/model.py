"""L2: JAX transformer model (fwd/bwd/optimizer) built on the L1 kernels.

The model is a GPT-style decoder-only transformer, split into *pipeline
stages* at compile time. For every stage we export flat-argument functions
(so the Rust coordinator can pass plain buffers over PJRT):

  stage 0       : fwd(params..., tokens i32[B,S])            -> y f32[B,S,H]
                  bwd(params..., tokens, dy)                 -> (grads...)
  middle stage  : fwd(params..., x f32[B,S,H])               -> y
                  bwd(params..., x, dy)                      -> (dx, grads...)
  last stage    : fwd(params..., x, targets i32[B,S])        -> loss f32[]
                  bwd(params..., x, targets)                 -> (dx, grads..., loss)
  every stage   : adam(params..., grads..., m..., v..., step)-> (params..., m..., v...)

The backward recomputes the stage forward from the stashed stage input
(stage-granular activation checkpointing) — exactly the CKPT dimension the
paper folds into its search space, and it keeps residuals out of the FFI.

Parameter order within a stage is deterministic (see ``stage_param_names``)
and recorded in the AOT manifest.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp

from .kernels import flash_attention, layer_norm, matmul_bias_act, ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration of the decoder-only transformer."""

    vocab: int = 8192
    hidden: int = 256
    layers: int = 4
    heads: int = 8
    seq: int = 128
    microbatch: int = 4
    ffn_mult: int = 4
    use_pallas: bool = True  # False -> pure-jnp reference path (oracle)

    @property
    def head_dim(self) -> int:
        assert self.hidden % self.heads == 0
        return self.hidden // self.heads

    @property
    def ffn(self) -> int:
        return self.hidden * self.ffn_mult

    def param_count(self) -> int:
        """Total trainable parameters."""
        per_layer = (
            2 * self.hidden  # ln1
            + 3 * self.hidden * self.hidden + 3 * self.hidden  # qkv
            + self.hidden * self.hidden + self.hidden  # proj
            + 2 * self.hidden  # ln2
            + self.hidden * self.ffn + self.ffn  # fc1
            + self.ffn * self.hidden + self.hidden  # fc2
        )
        emb = self.vocab * self.hidden + self.seq * self.hidden
        head = 2 * self.hidden + self.hidden * self.vocab
        return emb + self.layers * per_layer + head


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def layer_param_names(i: int) -> list[str]:
    return [
        f"l{i}.ln1.g", f"l{i}.ln1.b",
        f"l{i}.qkv.w", f"l{i}.qkv.b",
        f"l{i}.proj.w", f"l{i}.proj.b",
        f"l{i}.ln2.g", f"l{i}.ln2.b",
        f"l{i}.fc1.w", f"l{i}.fc1.b",
        f"l{i}.fc2.w", f"l{i}.fc2.b",
    ]


def layer_param_shapes(cfg: ModelConfig) -> list[tuple[int, ...]]:
    h, f = cfg.hidden, cfg.ffn
    return [
        (h,), (h,),
        (h, 3 * h), (3 * h,),
        (h, h), (h,),
        (h,), (h,),
        (h, f), (f,),
        (f, h), (h,),
    ]


def stage_param_names(cfg: ModelConfig, stage_layers: Sequence[int], first: bool, last: bool) -> list[str]:
    names: list[str] = []
    if first:
        names += ["emb.tok", "emb.pos"]
    for i in stage_layers:
        names += layer_param_names(i)
    if last:
        names += ["final.ln.g", "final.ln.b", "head.w"]
    return names


def stage_param_shapes(cfg: ModelConfig, stage_layers: Sequence[int], first: bool, last: bool) -> list[tuple[int, ...]]:
    shapes: list[tuple[int, ...]] = []
    if first:
        shapes += [(cfg.vocab, cfg.hidden), (cfg.seq, cfg.hidden)]
    for _ in stage_layers:
        shapes += layer_param_shapes(cfg)
    if last:
        shapes += [(cfg.hidden,), (cfg.hidden,), (cfg.hidden, cfg.vocab)]
    return shapes


def init_stage_params(cfg: ModelConfig, stage_layers: Sequence[int], first: bool, last: bool, key) -> list[jax.Array]:
    """GPT-2-style init: normal(0, 0.02) weights, zero bias, unit LN gain."""
    shapes = stage_param_shapes(cfg, stage_layers, first, last)
    names = stage_param_names(cfg, stage_layers, first, last)
    out = []
    for name, shape in zip(names, shapes):
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            out.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(".b") and len(shape) == 1:
            out.append(jnp.zeros(shape, jnp.float32))
        elif name.endswith(".w") or name.startswith("emb."):
            scale = 0.02
            if name.endswith("proj.w") or name.endswith("fc2.w"):
                # residual-branch scaling
                scale = 0.02 / math.sqrt(2 * cfg.layers)
            out.append(scale * jax.random.normal(sub, shape, jnp.float32))
        else:
            out.append(jnp.zeros(shape, jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Forward computation
# ---------------------------------------------------------------------------

def _transformer_layer(cfg: ModelConfig, p: list[jax.Array], x: jax.Array) -> jax.Array:
    """Pre-LN transformer layer. x: (B, S, H); p: the 12 layer params."""
    (ln1g, ln1b, qkvw, qkvb, projw, projb, ln2g, ln2b, fc1w, fc1b, fc2w, fc2b) = p
    b, s, h = x.shape
    rows = x.reshape(b * s, h)

    if cfg.use_pallas:
        normed = layer_norm(rows, ln1g, ln1b)
    else:
        normed = ref.layernorm_ref(rows, ln1g, ln1b)
    qkv = normed @ qkvw + qkvb[None, :]
    qkv = qkv.reshape(b, s, 3, cfg.heads, cfg.head_dim)
    q = qkv[:, :, 0].transpose(0, 2, 1, 3)
    k = qkv[:, :, 1].transpose(0, 2, 1, 3)
    v = qkv[:, :, 2].transpose(0, 2, 1, 3)
    if cfg.use_pallas:
        attn = flash_attention(q, k, v, True)
    else:
        attn = ref.attention_ref(q, k, v, causal=True)
    attn = attn.transpose(0, 2, 1, 3).reshape(b * s, h)
    x = rows + attn @ projw + projb[None, :]

    if cfg.use_pallas:
        normed2 = layer_norm(x, ln2g, ln2b)
        hidden = matmul_bias_act(normed2, fc1w, fc1b, "gelu")
    else:
        normed2 = ref.layernorm_ref(x, ln2g, ln2b)
        hidden = ref.matmul_bias_act_ref(normed2, fc1w, fc1b, activation="gelu")
    x = x + hidden @ fc2w + fc2b[None, :]
    return x.reshape(b, s, h)


def stage_forward(
    cfg: ModelConfig,
    stage_layers: Sequence[int],
    first: bool,
    last: bool,
    params: list[jax.Array],
    x: jax.Array,
    targets: jax.Array | None = None,
):
    """Forward for one pipeline stage with flat params.

    First stage: x is int32 tokens (B, S). Last stage returns scalar loss.
    """
    idx = 0
    if first:
        tok, pos = params[0], params[1]
        idx = 2
        h = tok[x] + pos[None, : cfg.seq, :]
    else:
        h = x
    for _ in stage_layers:
        h = _transformer_layer(cfg, params[idx : idx + 12], h)
        idx += 12
    if last:
        lng, lnb, headw = params[idx], params[idx + 1], params[idx + 2]
        b, s, hid = h.shape
        rows = h.reshape(b * s, hid)
        if cfg.use_pallas:
            rows = layer_norm(rows, lng, lnb)
        else:
            rows = ref.layernorm_ref(rows, lng, lnb)
        logits = rows @ headw
        assert targets is not None
        return ref.softmax_xent_ref(logits, targets.reshape(-1))
    return h


def full_forward_loss(cfg: ModelConfig, partition: Sequence[int], all_params: list[list[jax.Array]], tokens, targets):
    """Single-device reference: run every stage in sequence, return loss."""
    x = tokens
    n = len(partition)
    layer0 = 0
    for i, count in enumerate(partition):
        layers = list(range(layer0, layer0 + count))
        layer0 += count
        x = stage_forward(
            cfg, layers, first=(i == 0), last=(i == n - 1),
            params=all_params[i], x=x,
            targets=targets if i == n - 1 else None,
        )
    return x


# ---------------------------------------------------------------------------
# Stage bwd / optimizer (the exported entry points)
# ---------------------------------------------------------------------------

def make_stage_fns(cfg: ModelConfig, stage_layers: Sequence[int], first: bool, last: bool):
    """Build (fwd, bwd, adam) callables with flat-array signatures."""
    n_params = len(stage_param_names(cfg, stage_layers, first, last))

    if last:
        def fwd(*args):
            params = list(args[:n_params])
            x, targets = args[n_params], args[n_params + 1]
            return (stage_forward(cfg, stage_layers, first, last, params, x, targets),)

        if first:
            # Single-stage model: x is int tokens, no dx to propagate.
            def bwd(*args):
                params = list(args[:n_params])
                x, targets = args[n_params], args[n_params + 1]

                def lossfn(params_):
                    return stage_forward(cfg, stage_layers, first, last, params_, x, targets)

                loss, gparams = jax.value_and_grad(lossfn)(params)
                return (*gparams, loss)
        else:
            def bwd(*args):
                params = list(args[:n_params])
                x, targets = args[n_params], args[n_params + 1]

                def lossfn(params_, x_):
                    return stage_forward(cfg, stage_layers, first, last, params_, x_, targets)

                loss, grads = jax.value_and_grad(lossfn, argnums=(0, 1))(params, x)
                gparams, dx = grads
                return (dx, *gparams, loss)
    elif first:
        def fwd(*args):
            params = list(args[:n_params])
            x = args[n_params]
            return (stage_forward(cfg, stage_layers, first, last, params, x),)

        def bwd(*args):
            params = list(args[:n_params])
            x, dy = args[n_params], args[n_params + 1]

            def f(params_):
                return stage_forward(cfg, stage_layers, first, last, params_, x)

            _, vjp = jax.vjp(f, params)
            (gparams,) = vjp(dy)
            return tuple(gparams)
    else:
        def fwd(*args):
            params = list(args[:n_params])
            x = args[n_params]
            return (stage_forward(cfg, stage_layers, first, last, params, x),)

        def bwd(*args):
            params = list(args[:n_params])
            x, dy = args[n_params], args[n_params + 1]

            def f(params_, x_):
                return stage_forward(cfg, stage_layers, first, last, params_, x_)

            _, vjp = jax.vjp(f, params, x)
            gparams, dx = vjp(dy)
            return (dx, *gparams)

    def adam(*args, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
        """Flat Adam: (params, grads, m, v, step) -> (params', m', v')."""
        params = list(args[:n_params])
        grads = list(args[n_params : 2 * n_params])
        m = list(args[2 * n_params : 3 * n_params])
        v = list(args[3 * n_params : 4 * n_params])
        step = args[4 * n_params]  # f32 scalar, 1-based
        new_p, new_m, new_v = [], [], []
        for p, g, mi, vi in zip(params, grads, m, v):
            mi = b1 * mi + (1 - b1) * g
            vi = b2 * vi + (1 - b2) * g * g
            mhat = mi / (1 - b1**step)
            vhat = vi / (1 - b2**step)
            new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
            new_m.append(mi)
            new_v.append(vi)
        return (*new_p, *new_m, *new_v)

    return fwd, bwd, adam


def even_partition(layers: int, stages: int) -> list[int]:
    """Split `layers` into `stages` contiguous chunks, earlier stages larger."""
    base, rem = divmod(layers, stages)
    return [base + (1 if i < rem else 0) for i in range(stages)]
