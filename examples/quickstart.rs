//! Quickstart: ask Galvatron-BMW for the optimal hybrid-parallel plan for
//! BERT-Huge-32 on 8 RTX-TITAN GPUs under a 16 GB budget, compare it with
//! the pure baselines, and cross-check the plan on the discrete-event
//! simulator.
//!
//! Run: `cargo run --release --example quickstart`

use galvatron::cost::pipeline::Schedule;
use galvatron::experiments::{cluster, model};
use galvatron::search::baselines::run_method;
use galvatron::sim::simulate;
use galvatron::util::GIB;

fn main() {
    let mp = model("bert-huge-32");
    let cl = cluster("titan8", 16.0);
    println!(
        "model: {} ({:.0}M params) | cluster: {} x{} | budget 16 GB\n",
        mp.name,
        mp.total_params() / 1e6,
        cl.gpu.name,
        cl.n_devices
    );

    // 1. The automatic plan.
    let bmw = run_method("Galvatron-BMW", &mp, &cl, 512).expect("feasible");
    println!("Galvatron-BMW plan:");
    println!("{}", galvatron::experiments::figures::plan_summary(&bmw.plan));

    // 2. How it stacks up against pure parallelisms.
    println!("{:<22} {:>12} {:>8}", "method", "samples/s", "batch");
    for m in ["PyTorch DDP (DP)", "Megatron (TP)", "PyTorch GPipe (PP)", "FSDP/ZeRO-3 (SDP)", "Galvatron-BMW"] {
        match run_method(m, &mp, &cl, 512) {
            Some(o) => println!("{:<22} {:>12.2} {:>8}", m, o.throughput(), o.plan.batch),
            None => println!("{:<22} {:>12} {:>8}", m, "OOM", "-"),
        }
    }

    // 3. Independent cross-check on the event simulator.
    let sim = simulate(&mp, &cl, &bmw.plan, Schedule::OneFOneB, 1.3);
    println!(
        "\nsimulator cross-check: {:.2} samples/s (estimator said {:.2});\nper-stage peak memory: {:?} GiB",
        sim.throughput,
        bmw.throughput(),
        sim.stage_peak_mem.iter().map(|b| (b / GIB * 10.0).round() / 10.0).collect::<Vec<_>>()
    );
}
