//! Quickstart: ask Galvatron-BMW for the optimal hybrid-parallel plan for
//! BERT-Huge-32 on 8 RTX-TITAN GPUs under a 16 GB budget via the typed
//! `PlanRequest` builder, compare it with the pure baselines, persist the
//! plan as a JSON artifact, and cross-check it on the discrete-event
//! simulator.
//!
//! Run: `cargo run --release --example quickstart`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{MethodSpec, PlanError, PlanRequest, Planner};
use galvatron::parallel::Dim;
use galvatron::util::GIB;

fn main() -> anyhow::Result<()> {
    let planner = Planner::new();

    // 1. The automatic plan, via the builder API.
    let request = PlanRequest::new("bert-huge-32", "titan8").memory_gb(16.0).max_batch(512);
    let report = planner.plan(&request)?;
    println!("Galvatron-BMW plan:\n{}", report.plan.summary());

    // 2. How it stacks up against pure parallelisms (typed catalog — no
    //    magic strings; an OOM baseline is a typed Infeasible error).
    println!("{:<22} {:>12} {:>8}", "method", "samples/s", "batch");
    for method in [
        MethodSpec::Pure(Dim::Dp),
        MethodSpec::Pure(Dim::Tp),
        MethodSpec::PurePipeline,
        MethodSpec::Pure(Dim::Sdp),
        MethodSpec::Bmw { ckpt: true },
    ] {
        let name = method.canonical_name();
        match planner.plan(&request.clone().method(method)) {
            Ok(r) => println!("{:<22} {:>12.2} {:>8}", name, r.throughput, r.plan.batch),
            Err(PlanError::Infeasible { .. }) => {
                println!("{:<22} {:>12} {:>8}", name, "OOM", "-")
            }
            Err(e) => return Err(e.into()),
        }
    }

    // 3. Persist the plan artifact and reload it — the same JSON the CLI
    //    exchanges via `plan --out` / `simulate --plan`.
    let path = std::env::temp_dir().join("galvatron-quickstart-plan.json");
    report.save(&path)?;
    let loaded = galvatron::api::PlanReport::load(&path)?;
    assert_eq!(loaded, report);
    println!("\nplan artifact round-tripped through {}", path.display());

    // 4. Independent cross-check on the event simulator.
    let sim = planner.simulate_report(&loaded)?;
    println!(
        "simulator cross-check: {:.2} samples/s (estimator said {:.2});\nper-stage peak memory: {:?} GiB",
        sim.throughput,
        report.throughput,
        sim.stage_peak_mem.iter().map(|b| (b / GIB * 10.0).round() / 10.0).collect::<Vec<_>>()
    );
    Ok(())
}
