//! End-to-end validation driver (DESIGN.md §5 "E2E"): train a real
//! transformer through all three layers of the stack —
//!
//!   L1 Pallas kernels  ->  L2 JAX stage graphs  ->  AOT HLO text
//!   ->  L3 Rust coordinator (PP x DP pipeline, in-process collectives,
//!       Adam) on the PJRT CPU client
//!
//! — on a synthetic Markov corpus, logging the loss curve to CSV.
//!
//! Run:  make artifacts && cargo run --release --example train_e2e -- \
//!           [--steps 200] [--dp 2] [--microbatches 2] [--csv loss_curve.csv]
//!
//! The model configuration comes from the artifacts (preset `e2e` by
//! default; build with `--preset 100m` in python/compile/aot.py for the
//! ~100M-parameter variant — same code path, longer wallclock).

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::PlanReport;
use galvatron::coordinator::{Trainer, TrainerConfig};
use galvatron::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(&["repeat-batch"]);
    // Optional planner artifact (`galvatron plan --out plan.json`): print
    // what the planner promised so the run can be judged against it — the
    // plan → train leg of the artifact pipeline.
    if let Some(path) = args.get("plan") {
        let report = PlanReport::load(std::path::Path::new(path))?;
        println!(
            "plan artifact {path}: {} on {} via {}, est {:.2} samples/s",
            report.model,
            report.cluster,
            report.method.canonical_name(),
            report.throughput
        );
    }
    let cfg = TrainerConfig {
        artifacts_dir: args.get_or("artifacts", "artifacts").into(),
        steps: args.usize("steps", 200)?,
        dp: args.usize("dp", 2)?,
        microbatches: args.usize("microbatches", 2)?,
        log_every: args.usize("log-every", 10)?,
        seed: 0,
        repeat_batch: args.flag("repeat-batch"),
    };
    let csv = args.get_or("csv", "loss_curve.csv").to_string();

    let mut trainer = Trainer::new(cfg.clone())?;
    println!(
        "e2e training: {} params | pipeline stages per manifest | dp={} | {} samples/step",
        trainer.param_count,
        cfg.dp,
        trainer.samples_per_step()
    );
    let report = trainer.train()?;

    let first = report.losses.first().copied().unwrap_or(f64::NAN);
    let last = report.losses.last().copied().unwrap_or(f64::NAN);
    let min = report.losses.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "\nloss: {first:.4} -> {last:.4} (min {min:.4}) over {} steps",
        report.losses.len()
    );
    println!(
        "throughput: {:.2} samples/s ({} samples/step)",
        report.samples_per_sec(),
        report.samples_per_step
    );
    assert!(trainer.replicas_in_sync()?, "DP replicas diverged!");
    println!("DP replicas in sync: OK");

    std::fs::write(&csv, report.to_csv())?;
    println!("loss curve written to {csv}");
    Ok(())
}
