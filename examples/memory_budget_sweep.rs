//! Scenario: "how does the optimal plan change as device memory shrinks?"
//! — the workload that motivates the paper's intro (training under varying
//! GPU memory constraints).
//!
//! Sweeps BERT-Huge-32 and ViT-Huge-32 on titan8 across 6..24 GB budgets,
//! showing how Galvatron-BMW shifts between DP/SDP/TP/PP/CKPT and what
//! batch size / throughput each budget affords.
//!
//! Run: `cargo run --release --example memory_budget_sweep`

use galvatron::experiments::{cluster, model};
use galvatron::search::baselines::run_method;
use galvatron::util::table::Table;

fn dominant_dims(out: &galvatron::search::SearchOutcome) -> String {
    let mut dp = 0usize;
    let mut sdp = 0usize;
    let mut tp = 0usize;
    let mut ckpt = 0usize;
    for s in &out.plan.strategies {
        if s.dp() > 1 {
            dp += 1;
        }
        if s.sdp() > 1 {
            sdp += 1;
        }
        if s.tp() > 1 {
            tp += 1;
        }
        if s.ckpt {
            ckpt += 1;
        }
    }
    let total = out.plan.strategies.len();
    let mut parts = vec![format!("PP{}", out.plan.pp)];
    for (name, n) in [("DP", dp), ("SDP", sdp), ("TP", tp), ("CKPT", ckpt)] {
        if n > 0 {
            parts.push(format!("{name}:{n}/{total}"));
        }
    }
    parts.join(" ")
}

fn main() {
    for mname in ["bert-huge-32", "vit-huge-32"] {
        let mp = model(mname);
        println!("\n=== {} on titan8: memory budget sweep ===", mp.name);
        let mut t = Table::new(["budget (GB)", "samples/s", "batch", "plan shape"]);
        for budget in [6.0, 8.0, 10.0, 12.0, 16.0, 20.0, 24.0] {
            let cl = cluster("titan8", budget);
            match run_method("Galvatron-BMW", &mp, &cl, 512) {
                Some(out) => t.row([
                    format!("{budget}"),
                    format!("{:.2}", out.throughput()),
                    out.plan.batch.to_string(),
                    dominant_dims(&out),
                ]),
                None => t.row([format!("{budget}"), "OOM".into(), "-".into(), "-".into()]),
            }
        }
        t.print();
    }
}
