//! Scenario: "how does the optimal plan change as device memory shrinks?"
//! — the workload that motivates the paper's intro (training under varying
//! GPU memory constraints).
//!
//! Sweeps BERT-Huge-32 and ViT-Huge-32 on titan8 across 6..24 GB budgets
//! through the typed `PlanRequest` API, showing how Galvatron-BMW shifts
//! between DP/SDP/TP/PP/CKPT and what batch size / throughput each budget
//! affords. OOM shows up as a typed `PlanError::Infeasible`, not a `None`.
//!
//! Run: `cargo run --release --example memory_budget_sweep`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{PlanError, PlanReport, PlanRequest};
use galvatron::util::table::Table;

fn dominant_dims(report: &PlanReport) -> String {
    let mut dp = 0usize;
    let mut sdp = 0usize;
    let mut tp = 0usize;
    let mut ckpt = 0usize;
    for s in &report.plan.strategies {
        if s.dp() > 1 {
            dp += 1;
        }
        if s.sdp() > 1 {
            sdp += 1;
        }
        if s.tp() > 1 {
            tp += 1;
        }
        if s.ckpt {
            ckpt += 1;
        }
    }
    let total = report.plan.strategies.len();
    let mut parts = vec![format!("PP{}", report.plan.pp)];
    for (name, n) in [("DP", dp), ("SDP", sdp), ("TP", tp), ("CKPT", ckpt)] {
        if n > 0 {
            parts.push(format!("{name}:{n}/{total}"));
        }
    }
    parts.join(" ")
}

fn main() -> anyhow::Result<()> {
    for mname in ["bert-huge-32", "vit-huge-32"] {
        println!("\n=== {mname} on titan8: memory budget sweep ===");
        let mut t = Table::new(["budget (GB)", "samples/s", "batch", "plan shape"]);
        for budget in [6.0, 8.0, 10.0, 12.0, 16.0, 20.0, 24.0] {
            let request = PlanRequest::new(mname, "titan8").memory_gb(budget).max_batch(512);
            match request.plan() {
                Ok(report) => t.row([
                    format!("{budget}"),
                    format!("{:.2}", report.throughput),
                    report.plan.batch.to_string(),
                    dominant_dims(&report),
                ]),
                Err(PlanError::Infeasible { .. }) => {
                    t.row([format!("{budget}"), "OOM".into(), "-".into(), "-".into()])
                }
                Err(e) => return Err(e.into()),
            }
        }
        t.print();
    }
    Ok(())
}
