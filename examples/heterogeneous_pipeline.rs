//! Scenario: imbalanced models (paper §IV-B / §VII-E/F) — T5-512/4 (a
//! 512-token encoder feeding a 4-token decoder) and Swin-Huge (four
//! hetero stages) make naive even pipeline partitions either OOM or idle.
//!
//! This example contrasts, for both models:
//!   * even layer partition,
//!   * memory-balanced partition p_m,
//!   * time-balanced partition p_t,
//!   * the bi-objective partition found by Galvatron-BMW,
//! reporting simulated per-stage memory/time and the Eq. 6 balance degrees.
//!
//! Run: `cargo run --release --example heterogeneous_pipeline`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{MethodSpec, PlanRequest};
use galvatron::cost::pipeline::Schedule;
use galvatron::experiments::{cluster, model};
use galvatron::search::base::{evaluate_partition, SearchConfig};
use galvatron::search::bmw::{memory_balanced_partition, partition_str};
use galvatron::search::decision_tree::SpaceOptions;
use galvatron::search::partition::{balanced_partition, even_partition};
use galvatron::sim::simulate;
use galvatron::util::table::Table;
use galvatron::util::GIB;

fn main() {
    let pp = 4usize;
    let m = 8usize;
    for (mname, batch) in [("t5-512/4-48", 64usize), ("swin-huge-48", 64)] {
        let mp = model(mname);
        let cl = cluster("a100x16", 16.0);
        let cfg = SearchConfig {
            space: SpaceOptions::default().no_ckpt(),
            pp_degrees: Some(vec![pp]),
            max_batch: batch,
            ..Default::default()
        };
        let group = cl.n_devices() / pp;
        let b_m = batch as f64 / m as f64;
        let act_w: Vec<f64> = mp.layers.iter().map(|l| l.act_bytes * b_m / group as f64).collect();
        let ms_w: Vec<f64> = (0..mp.n_layers())
            .map(|i| (mp.layers[i].params + mp.extra_params(i)) * 16.0 / group as f64)
            .collect();
        let flops_w: Vec<f64> = mp.layers.iter().map(|l| l.flops_fwd).collect();

        let partitions: Vec<(&str, Vec<usize>)> = vec![
            ("even", even_partition(mp.n_layers(), pp)),
            ("memory-balanced", memory_balanced_partition(&act_w, &ms_w, pp, m, Schedule::OneFOneB)),
            ("time-balanced", balanced_partition(&flops_w, pp)),
            (
                "bi-objective",
                // The full planner, through the typed API, pinned to the
                // same PP degree / no-CKPT space as the fixed partitions.
                PlanRequest::new(mname, "a100x16")
                    .memory_gb(16.0)
                    .max_batch(batch)
                    .method(MethodSpec::Bmw { ckpt: false })
                    .pipeline_degrees(&[pp])
                    .plan()
                    .map(|r| r.plan.partition)
                    .unwrap_or_else(|_| even_partition(mp.n_layers(), pp)),
            ),
        ];

        println!("\n=== {} | B={batch}, m={m}, P={pp}, a100x16 @16G ===", mp.name);
        let mut t = Table::new([
            "partition", "p", "stage mem GiB", "stage time rel", "alpha_t", "alpha_m", "samples/s",
        ]);
        for (name, part) in partitions {
            match evaluate_partition(&mp, &cl, &cfg, batch, pp, m, &part) {
                Some((out, _)) => {
                    let sim = simulate(&mp, &cl, &out.plan, Schedule::OneFOneB, 1.3);
                    let tmax = sim.stage_mb_time.iter().cloned().fold(0.0, f64::max);
                    t.row([
                        name.to_string(),
                        partition_str(&part),
                        sim.stage_peak_mem.iter().map(|x| format!("{:.1}", x / GIB)).collect::<Vec<_>>().join("/"),
                        sim.stage_mb_time.iter().map(|x| format!("{:.2}", x / tmax)).collect::<Vec<_>>().join("/"),
                        format!("{:.3}", sim.alpha_t()),
                        format!("{:.3}", sim.alpha_m()),
                        format!("{:.2}", sim.throughput),
                    ]);
                }
                None => t.row([
                    name.to_string(),
                    partition_str(&part),
                    "OOM".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
        t.print();
    }
}
