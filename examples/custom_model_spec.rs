//! Scenario: plan *your own* model — the declarative `ModelSpec` front
//! door (ISSUE 4). Describes a GQA + MoE decoder-only model inline, plans
//! it under bf16 + ZeRO numerics on a mixed-island cluster, and then plans
//! a spec loaded from a JSON file (`examples/models/gpt3-1.3b.json`) the
//! way the CLI's `--model-file` does.
//!
//! Run: `cargo run --release --example custom_model_spec`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{PlanRequest, Planner};
use galvatron::model::{
    BlockSpec, Dtype, EmbeddingSpec, Family, ModelSpec, MoeSpec, TrainConfig,
};
use galvatron::util::GIB;

fn main() -> anyhow::Result<()> {
    let planner = Planner::new();

    // 1. An inline spec: a 1.6B-ish decoder-only LM with grouped-query
    //    attention and a mixture-of-experts FFN every block.
    let spec = ModelSpec {
        name: "MoE-GQA-LM".into(),
        family: Family::DecoderOnly,
        blocks: vec![BlockSpec {
            kv_heads: Some(4),                              // GQA: 16 q heads, 4 kv heads
            moe: Some(MoeSpec { experts: 8, top_k: 2 }),    // 8 experts, top-2 routing
            ..BlockSpec::dense(24, 2048, 16, 2048)
        }],
        embedding: Some(EmbeddingSpec { vocab: 50257, positions: 2048, ..Default::default() }),
        head: None,
    };
    println!("spec JSON:\n{}\n", spec.to_json());

    // 2. Plan it with lean numerics: bf16 activations/params (fp32 master
    //    weights accounted), Adam, ZeRO-sharded optimizer state.
    let train = TrainConfig { dtype: Dtype::Bf16, zero: true, ..Default::default() };
    let report = PlanRequest::new("ignored", "hetero4")
        .model_spec(spec)
        .train_config(train)
        .max_batch(64)
        .plan()?;
    println!("{}", report.render());

    // 3. The artifact records the spec + train config, so it re-simulates
    //    without the original file or builder.
    let sim = planner.simulate_report(&report)?;
    println!(
        "simulated: {:.2} samples/s; per-stage peak {:?} GiB (capacity {:?} GiB)\n",
        sim.throughput,
        sim.stage_peak_mem.iter().map(|b| (b / GIB * 10.0).round() / 10.0).collect::<Vec<_>>(),
        sim.stage_capacity.iter().map(|b| b / GIB).collect::<Vec<_>>(),
    );

    // 4. The file-based form (what `--model-file` does). fp32 vs bf16+ZeRO
    //    shows the dtype/optimizer footprint directly.
    let file = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/models/gpt3-1.3b.json");
    for (label, req) in [
        ("fp32+adam", PlanRequest::new("ignored", "hetero4").model_file(file).max_batch(64)),
        (
            "bf16+adam+zero",
            PlanRequest::new("ignored", "hetero4")
                .model_file(file)
                .train_config(train)
                .max_batch(64),
        ),
    ] {
        match req.plan() {
            Ok(r) => {
                let peak = r
                    .stages
                    .iter()
                    .map(|s| s.peak_mem_bytes)
                    .fold(0.0f64, f64::max);
                println!(
                    "GPT3-1.3B {label:<15} {:.2} samples/s, batch {}, max stage peak {:.1} GiB",
                    r.throughput,
                    r.plan.batch,
                    peak / GIB
                );
            }
            Err(e) => println!("GPT3-1.3B {label:<15} {e}"),
        }
    }
    Ok(())
}
