//! Calibrated cost-model backend: synthesize a `ProfileDb` from the
//! analytic model (the `galvatron calibrate --synthetic` form), plan with
//! it, verify the recorded provenance, and show how a derated calibration
//! (slower measured compute, lossy links) moves the estimates.
//!
//! The real-measurement pipeline is the same three steps with
//! `galvatron calibrate` (PJRT layer profiles + collectives
//! micro-benchmark) producing the DB instead of `ProfileDb::synthetic`.
//!
//! Run: `cargo run --release --example calibrated_cost_model`

#![allow(clippy::unwrap_used, clippy::expect_used)]

use galvatron::api::{resolve_cluster_name, CostModel, MethodSpec, PlanRequest, Planner, ProfileDb};

fn main() -> anyhow::Result<()> {
    let planner = Planner::new();
    let cluster = resolve_cluster_name("titan8")?;

    // 1. A synthetic DB: exact zoo shape coverage at the nominal FLOP
    //    rate, collective points exactly on the bytes/bw line (alpha=0).
    let db = ProfileDb::synthetic(&cluster);
    println!(
        "synthetic profile db: {} layer samples, {} collective points, hash {}",
        db.layers.len(),
        db.collectives.len(),
        db.content_hash_hex()
    );

    let request = PlanRequest::new("bert-huge-32", "titan8")
        .memory_gb(16.0)
        .max_batch(64)
        .method(MethodSpec::Bmw { ckpt: true });

    // 2. Analytic vs synthetic-calibrated: byte-identical plans, but the
    //    calibrated artifact records which cost model produced it.
    let analytic = planner.plan(&request)?;
    let calibrated = planner.plan(&request.clone().cost_model(CostModel::calibrated(db.clone())))?;
    assert_eq!(analytic.plan, calibrated.plan);
    assert_eq!(analytic.throughput.to_bits(), calibrated.throughput.to_bits());
    println!(
        "synthetic calibration reproduces the analytic plan: batch {}, {:.2} samples/s",
        calibrated.plan.batch, calibrated.throughput
    );
    println!(
        "recorded provenance: {}",
        calibrated.cost_model.as_ref().expect("calibrated plans record provenance").label()
    );

    // 3. A derated calibration — as a real host measurement might look:
    //    70% compute efficiency, 50us collective latency, 80% link
    //    efficiency. The planner re-prices the whole search space.
    let mut measured = db;
    let eff = measured.ref_flops * 0.7;
    for s in &mut measured.layers {
        s.effective_flops = eff;
    }
    measured.alpha = 5e-5;
    measured.beta = measured.ref_bw * 0.8;
    let derated =
        planner.plan(&request.clone().cost_model(CostModel::calibrated(measured.clone())))?;
    println!(
        "derated calibration: {:.2} samples/s (analytic said {:.2}); plan batch {} vs {}",
        derated.throughput, analytic.throughput, derated.plan.batch, analytic.plan.batch
    );

    // 4. Simulate under the same backend the plan was priced with (the
    //    `simulate --plan plan.json --profile-db db.json` leg).
    let sim = planner
        .simulate_report_costed(&derated, &CostModel::calibrated(measured))?;
    println!(
        "simulator cross-check under the calibrated backend: {:.2} samples/s",
        sim.throughput
    );
    Ok(())
}
